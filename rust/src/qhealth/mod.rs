//! §Numeric health: quantization-quality monitoring for the serving path.
//!
//! PR 8's tracing answers *how long* a request took; this module answers
//! *how healthy its quantized math was*. Four signal families, all
//! collected at dispatch/epilogue granularity (never inside micro-kernel
//! loops — the `no-timing-in-kernels` pattern):
//!
//! * **Activation drift** ([`Recorder::record_act`]): per act-site observed
//!   min/max and clip fraction against the calibrated
//!   `ModelArtifact.act_params` range, a log-bucketed drift histogram
//!   (per-mille range overshoot), and an EWMA clip-fraction alarm that
//!   flips the `splitquant_quant_drift` gauge — calibration-time ranges go
//!   stale under real traffic, and this is the online detector.
//! * **Cluster occupancy** ([`Recorder::record_dispatch`]): per-layer
//!   lower/middle/upper cluster code counts
//!   ([`crate::parallel::kernels::cluster_occupancy`]) and dead-cluster
//!   detection.
//! * **Outlier-hatch telemetry** ([`Recorder::record_ocs`]):
//!   `act_outlier_columns` / `ocs_expand_acts` hit rates and expansion
//!   ratios per layer.
//! * **Shadow fidelity** ([`Recorder::record_shadow`] via
//!   [`crate::model::QuantizedBert::shadow_sample`]): 1-in-N served
//!   requests deterministically re-run through the FP32 reference engine
//!   off the hot batch ([`ShadowConfig`] — seeded and replayable like
//!   `FaultyIo`'s schedule), recording logit-KL and top-1 agreement.
//!
//! **Disabled cost.** Every emission site is guarded by [`enabled`] — one
//! relaxed atomic load, the same contract as [`crate::trace::enabled`].
//! With the switch off nothing locks, nothing allocates, and served logits
//! are bit-identical (regression-tested in `model::qbert`).
//!
//! **Determinism.** All aggregate state lives in `BTreeMap`s and every
//! rendered artifact ([`render`], [`bench_rows`]) iterates them in sorted
//! order — `splitquant doctor` output is byte-deterministic for a given
//! seed (the `deterministic-iteration` lint rule covers this module).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::report::bench_json::BenchRecord;
use crate::util::stats::LogHistogram;
use crate::util::sync::lock_recover;

/// Master switch: one relaxed load on every emission entry point.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is numeric-health monitoring enabled? One relaxed atomic load — the
/// entire cost of every recording site while off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn numeric-health monitoring on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// EWMA smoothing factor for the per-site clip-fraction alarm.
const EWMA_ALPHA: f64 = 0.25;

/// EWMA clip fraction above which a site's drift alarm latches: more than
/// 5 % of activation values landing outside the calibrated range is no
/// longer quantization noise, it is distribution drift.
const CLIP_ALARM: f64 = 0.05;

/// Deterministic 1-in-N shadow-sampling schedule, seeded and replayable
/// (the [`crate::shardstore::FaultyIo`] idiom): whether request `seq` is
/// shadow-sampled is a pure function of `(seed, seq)`, so a replay run
/// with the same seed samples exactly the same requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShadowConfig {
    /// Schedule seed (replays reproduce the same sample set).
    pub seed: u64,
    /// Sample 1-in-`rate` requests; `0` disables sampling entirely.
    pub rate: u64,
}

impl ShadowConfig {
    /// splitmix64 finalizer — the standard invertible avalanche mix.
    fn mix(mut z: u64) -> u64 {
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Should request number `seq` be shadow-sampled? Pure in
    /// `(self.seed, seq)`; over many requests the hit rate converges to
    /// `1/rate`.
    pub fn fires(&self, seq: u64) -> bool {
        if self.rate == 0 {
            return false;
        }
        if self.rate == 1 {
            return true;
        }
        Self::mix(self.seed ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15)) % self.rate == 0
    }
}

/// Per-activation-site drift state (keyed by the `BertConfig::act_sites`
/// index the executor consults at that linear's input).
#[derive(Debug, Default)]
struct SiteHealth {
    calibrated: Option<(f32, f32)>,
    observed_lo: f32,
    observed_hi: f32,
    values: u64,
    clipped: u64,
    batches: u64,
    /// Per-dispatch range overshoot in per-mille of the calibrated width.
    drift_pm: LogHistogram,
    ewma_clip: f64,
    alarm: bool,
}

/// Per-layer dispatch telemetry: cluster occupancy + OCS hatch activity.
#[derive(Debug, Default)]
struct LayerHealth {
    occupancy: [u64; 3],
    dispatches: u64,
    ocs_calls: u64,
    ocs_hits: u64,
    outlier_cols: u64,
    total_cols: u64,
}

/// Shadow-fidelity aggregates (quantized engine vs FP32 reference).
#[derive(Debug, Default)]
struct ShadowStats {
    samples: u64,
    top1_agree: u64,
    /// logit-KL per sampled row, in micro-nats (log-bucketed).
    kl_micro_nats: LogHistogram,
}

#[derive(Debug, Default)]
struct Inner {
    sites: BTreeMap<usize, SiteHealth>,
    layers: BTreeMap<String, LayerHealth>,
    shadow: ShadowStats,
}

/// Thread-safe numeric-health accumulator, owned by the executor
/// ([`crate::model::QuantizedBert`] holds one behind an `Arc`) and read by
/// the server on metrics folds. Recording sites must check [`enabled`]
/// before calling in — the recorder itself always accepts.
#[derive(Debug, Default)]
pub struct Recorder {
    inner: Mutex<Inner>,
}

impl Recorder {
    /// Record one activation-site observation: `values` is the tensor
    /// feeding a fused linear whose input maps to act site `site`;
    /// `calibrated` is that site's deployed dequant range (`None` when no
    /// activation params are deployed — observed min/max still accumulate,
    /// clip/drift need a range to compare against).
    pub fn record_act(&self, site: usize, calibrated: Option<(f32, f32)>, values: &[f32]) {
        if values.is_empty() {
            return;
        }
        let (clipped, lo, hi) = match calibrated {
            Some((clo, chi)) => crate::quant::observer::clip_stats(values, clo, chi),
            None => {
                let (lo, hi) = crate::util::stats::min_max(values);
                (0, lo, hi)
            }
        };
        let mut g = lock_recover(&self.inner);
        let s = g.sites.entry(site).or_insert_with(|| SiteHealth {
            observed_lo: f32::INFINITY,
            observed_hi: f32::NEG_INFINITY,
            ..SiteHealth::default()
        });
        s.calibrated = calibrated.or(s.calibrated);
        s.observed_lo = s.observed_lo.min(lo);
        s.observed_hi = s.observed_hi.max(hi);
        s.values += values.len() as u64;
        s.clipped += clipped;
        s.batches += 1;
        if let Some((clo, chi)) = calibrated {
            let width = (chi - clo).max(f32::MIN_POSITIVE) as f64;
            let over = (hi - chi).max(0.0) as f64 + (clo - lo).max(0.0) as f64;
            s.drift_pm.record_us((over / width * 1000.0).round() as u64);
            let clip_frac = clipped as f64 / values.len() as f64;
            s.ewma_clip = EWMA_ALPHA * clip_frac + (1.0 - EWMA_ALPHA) * s.ewma_clip;
            if s.ewma_clip > CLIP_ALARM {
                s.alarm = true; // latches until the recorder is replaced
            }
        }
    }

    /// Record one fused-linear dispatch for `layer`: `occ` is the weight's
    /// per-cluster code count ([`crate::parallel::kernels::cluster_occupancy`]).
    pub fn record_dispatch(&self, layer: &str, occ: [u64; 3]) {
        let mut g = lock_recover(&self.inner);
        let l = g.layers.entry(layer.to_string()).or_default();
        for (acc, n) in l.occupancy.iter_mut().zip(occ) {
            *acc += n;
        }
        l.dispatches += 1;
    }

    /// Record one OCS escape-hatch evaluation for `layer`: the activation
    /// had `total_cols` columns, of which `outlier_cols` exceeded the
    /// outlier ratio (a *hit* — the expanded matmul ran — when nonzero).
    pub fn record_ocs(&self, layer: &str, total_cols: u64, outlier_cols: u64) {
        let mut g = lock_recover(&self.inner);
        let l = g.layers.entry(layer.to_string()).or_default();
        l.ocs_calls += 1;
        l.ocs_hits += u64::from(outlier_cols > 0);
        l.outlier_cols += outlier_cols;
        l.total_cols += total_cols;
    }

    /// Record one shadow-sampled row: `kl_nats` = logit-KL(reference ‖
    /// served), `top1_agree` = both engines picked the same class.
    pub fn record_shadow(&self, kl_nats: f64, top1_agree: bool) {
        let mut g = lock_recover(&self.inner);
        g.shadow.samples += 1;
        g.shadow.top1_agree += u64::from(top1_agree);
        g.shadow.kl_micro_nats.record_us((kl_nats.max(0.0) * 1e6).round() as u64);
    }

    /// Point-in-time copy of everything recorded so far, pre-sorted (the
    /// `BTreeMap` order) so every consumer renders deterministically.
    pub fn snapshot(&self) -> QHealthSnapshot {
        let g = lock_recover(&self.inner);
        QHealthSnapshot {
            sites: g
                .sites
                .iter()
                .map(|(&site, s)| SiteSnapshot {
                    site,
                    calibrated: s.calibrated,
                    observed: (s.values > 0).then_some((s.observed_lo, s.observed_hi)),
                    values: s.values,
                    clipped: s.clipped,
                    batches: s.batches,
                    ewma_clip: s.ewma_clip,
                    alarm: s.alarm,
                    drift_p50_permille: s.drift_pm.quantile_us(0.5),
                    drift_max_permille: s.drift_pm.quantile_us(1.0),
                })
                .collect(),
            layers: g
                .layers
                .iter()
                .map(|(name, l)| LayerSnapshot {
                    layer: name.clone(),
                    occupancy: l.occupancy,
                    dead_clusters: l.occupancy.iter().filter(|&&n| n == 0).count() as u32,
                    dispatches: l.dispatches,
                    ocs_calls: l.ocs_calls,
                    ocs_hits: l.ocs_hits,
                    outlier_cols: l.outlier_cols,
                    total_cols: l.total_cols,
                })
                .collect(),
            shadow: ShadowSnapshot {
                samples: g.shadow.samples,
                top1_agree: g.shadow.top1_agree,
                kl_mean_micro_nats: g.shadow.kl_micro_nats.mean_us(),
                kl_p50_micro_nats: g.shadow.kl_micro_nats.quantile_us(0.5),
                kl_max_micro_nats: g.shadow.kl_micro_nats.quantile_us(1.0),
            },
        }
    }
}

/// One activation site's drift summary (see [`Recorder::record_act`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SiteSnapshot {
    /// `BertConfig::act_sites` index.
    pub site: usize,
    /// Deployed calibration range (dequant range of the site's `QParams`).
    pub calibrated: Option<(f32, f32)>,
    /// Observed activation min/max across all dispatches, when any.
    pub observed: Option<(f32, f32)>,
    /// Total activation values observed.
    pub values: u64,
    /// Values outside the calibrated range.
    pub clipped: u64,
    /// Dispatches observed.
    pub batches: u64,
    /// EWMA of the per-dispatch clip fraction.
    pub ewma_clip: f64,
    /// Latched drift alarm (EWMA clip fraction exceeded the threshold).
    pub alarm: bool,
    /// Median per-dispatch range overshoot, per-mille of calibrated width.
    pub drift_p50_permille: u64,
    /// Maximum per-dispatch range overshoot, per-mille.
    pub drift_max_permille: u64,
}

impl SiteSnapshot {
    /// Fraction of observed values outside the calibrated range.
    pub fn clip_fraction(&self) -> f64 {
        if self.values == 0 {
            0.0
        } else {
            self.clipped as f64 / self.values as f64
        }
    }
}

/// One layer's cluster-occupancy and OCS-hatch summary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSnapshot {
    /// Parameter name of the fused linear.
    pub layer: String,
    /// Cumulative lower/middle/upper cluster code counts across dispatches.
    pub occupancy: [u64; 3],
    /// Clusters with zero occupancy — a dead cluster wastes one of the
    /// three split ranges (SplitQuant's accuracy premise is that all three
    /// carry signal).
    pub dead_clusters: u32,
    /// Fused-linear dispatches recorded for this layer.
    pub dispatches: u64,
    /// OCS escape-hatch evaluations.
    pub ocs_calls: u64,
    /// Evaluations that found outlier columns (the expanded matmul ran).
    pub ocs_hits: u64,
    /// Total outlier columns across evaluations.
    pub outlier_cols: u64,
    /// Total activation columns across evaluations.
    pub total_cols: u64,
}

impl LayerSnapshot {
    /// Mean activation-width expansion ratio of the OCS hatch
    /// (`1.0` = never expanded).
    pub fn expansion_ratio(&self) -> f64 {
        if self.total_cols == 0 {
            1.0
        } else {
            (self.total_cols + self.outlier_cols) as f64 / self.total_cols as f64
        }
    }
}

/// Shadow-fidelity summary (quantized engine vs FP32 reference).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ShadowSnapshot {
    /// Rows shadow-sampled.
    pub samples: u64,
    /// Rows where both engines picked the same top-1 class.
    pub top1_agree: u64,
    /// Mean logit-KL(reference ‖ served), micro-nats.
    pub kl_mean_micro_nats: f64,
    /// Median logit-KL, micro-nats.
    pub kl_p50_micro_nats: u64,
    /// Max logit-KL, micro-nats.
    pub kl_max_micro_nats: u64,
}

impl ShadowSnapshot {
    /// Top-1 agreement rate over sampled rows (`1.0` when nothing sampled).
    pub fn agree_rate(&self) -> f64 {
        if self.samples == 0 {
            1.0
        } else {
            self.top1_agree as f64 / self.samples as f64
        }
    }
}

/// Everything [`Recorder::snapshot`] captures, pre-sorted for
/// deterministic rendering. Embedded in serving
/// [`crate::coordinator::Metrics`] as an `Option`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct QHealthSnapshot {
    /// Per-activation-site drift summaries, sorted by site index.
    pub sites: Vec<SiteSnapshot>,
    /// Per-layer dispatch summaries, sorted by layer name.
    pub layers: Vec<LayerSnapshot>,
    /// Shadow-fidelity summary.
    pub shadow: ShadowSnapshot,
}

impl QHealthSnapshot {
    /// True when any site's drift alarm has latched — the
    /// `splitquant_quant_drift` gauge, folded into `splitquant_degraded`.
    pub fn drift_alarmed(&self) -> bool {
        self.sites.iter().any(|s| s.alarm)
    }

    /// True when nothing was recorded at all.
    pub fn is_empty(&self) -> bool {
        self.sites.is_empty() && self.layers.is_empty() && self.shadow.samples == 0
    }
}

/// KL divergence between the softmax distributions of two logit rows,
/// `KL(softmax(reference) ‖ softmax(served))`, in nats. Computed in f64
/// with max-subtraction for stability; non-finite inputs and length
/// mismatches return `f64::INFINITY` (maximally suspicious, never a
/// panic on the serving path).
pub fn logit_kl(reference: &[f32], served: &[f32]) -> f64 {
    if reference.is_empty()
        || reference.len() != served.len()
        || reference.iter().chain(served).any(|v| !v.is_finite())
    {
        return f64::INFINITY;
    }
    let softmax = |row: &[f32]| -> Vec<f64> {
        let max = row.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v as f64));
        let exps: Vec<f64> = row.iter().map(|&v| (v as f64 - max).exp()).collect();
        let sum: f64 = exps.iter().sum();
        exps.iter().map(|e| (e / sum).max(1e-300)).collect()
    };
    let p = softmax(reference);
    let q = softmax(served);
    p.iter().zip(&q).map(|(pi, qi)| pi * (pi / qi).ln()).sum::<f64>().max(0.0)
}

/// `qhealth-<layer>` rows (plus one `qhealth-shadow` row when sampling
/// ran) for `BENCH_serving.json`: keyed by `(bench, shape, engine)` so
/// [`crate::report::bench_json::merge_write`] replaces them idempotently.
pub fn bench_rows(snap: &QHealthSnapshot, shape: &str, engine: &str) -> Vec<BenchRecord> {
    let mut rows = Vec::new();
    for l in &snap.layers {
        rows.push(BenchRecord {
            bench: format!("qhealth-{}", l.layer),
            shape: shape.to_string(),
            engine: engine.to_string(),
            ns_per_iter: 0.0,
            gb_per_s: 0.0,
            extra: vec![
                ("occupancy_lower".to_string(), l.occupancy[0] as f64),
                ("occupancy_middle".to_string(), l.occupancy[1] as f64),
                ("occupancy_upper".to_string(), l.occupancy[2] as f64),
                ("dead_clusters".to_string(), l.dead_clusters as f64),
                ("dispatches".to_string(), l.dispatches as f64),
                ("ocs_calls".to_string(), l.ocs_calls as f64),
                ("ocs_hits".to_string(), l.ocs_hits as f64),
                ("expansion_ratio".to_string(), l.expansion_ratio()),
            ],
        });
    }
    if snap.shadow.samples > 0 {
        rows.push(BenchRecord {
            bench: "qhealth-shadow".to_string(),
            shape: shape.to_string(),
            engine: engine.to_string(),
            ns_per_iter: 0.0,
            gb_per_s: 0.0,
            extra: vec![
                ("samples".to_string(), snap.shadow.samples as f64),
                ("top1_agree".to_string(), snap.shadow.top1_agree as f64),
                ("agree_rate".to_string(), snap.shadow.agree_rate()),
                ("kl_mean_micro_nats".to_string(), snap.shadow.kl_mean_micro_nats),
                ("kl_p50_micro_nats".to_string(), snap.shadow.kl_p50_micro_nats as f64),
                ("kl_max_micro_nats".to_string(), snap.shadow.kl_max_micro_nats as f64),
            ],
        });
    }
    rows
}

/// Render a snapshot as the sorted per-layer health report printed by
/// `splitquant doctor`. Byte-deterministic: sites ascend numerically,
/// layers ascend lexicographically, floats print at fixed precision.
pub fn render(snap: &QHealthSnapshot) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("qhealth report\n");
    let _ = writeln!(
        out,
        "sites={} layers={} drift_alarm={}",
        snap.sites.len(),
        snap.layers.len(),
        if snap.drift_alarmed() { "yes" } else { "no" }
    );
    for s in &snap.sites {
        let cal = match s.calibrated {
            Some((lo, hi)) => format!("[{lo:.4},{hi:.4}]"),
            None => "none".to_string(),
        };
        let obs = match s.observed {
            Some((lo, hi)) => format!("[{lo:.4},{hi:.4}]"),
            None => "none".to_string(),
        };
        let _ = writeln!(
            out,
            "site {:>3}: calibrated={cal} observed={obs} clip={:.4} ewma_clip={:.4} \
             drift_p50={}pm drift_max={}pm batches={} alarm={}",
            s.site,
            s.clip_fraction(),
            s.ewma_clip,
            s.drift_p50_permille,
            s.drift_max_permille,
            s.batches,
            if s.alarm { "YES" } else { "no" },
        );
    }
    for l in &snap.layers {
        let _ = writeln!(
            out,
            "layer {}: occupancy=[{},{},{}] dead={} dispatches={} ocs={}/{} \
             outlier_cols={}/{} expansion={:.4}",
            l.layer,
            l.occupancy[0],
            l.occupancy[1],
            l.occupancy[2],
            l.dead_clusters,
            l.dispatches,
            l.ocs_hits,
            l.ocs_calls,
            l.outlier_cols,
            l.total_cols,
            l.expansion_ratio(),
        );
    }
    let sh = &snap.shadow;
    let _ = writeln!(
        out,
        "shadow: samples={} top1_agree={} agree_rate={:.4} kl_mean={:.1}un \
         kl_p50={}un kl_max={}un",
        sh.samples,
        sh.top1_agree,
        sh.agree_rate(),
        sh.kl_mean_micro_nats,
        sh.kl_p50_micro_nats,
        sh.kl_max_micro_nats,
    );
    out
}

/// Serializes unit tests (across modules of this crate's test binary)
/// that flip the process-global [`set_enabled`] switch, so concurrent
/// tests can't observe each other's toggles.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    lock_recover(&LOCK)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn master_switch_defaults_off_and_toggles() {
        let _g = test_guard();
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
    }

    #[test]
    fn shadow_schedule_is_deterministic_and_near_rate() {
        let sc = ShadowConfig { seed: 42, rate: 8 };
        let a: Vec<bool> = (0..10_000).map(|s| sc.fires(s)).collect();
        let b: Vec<bool> = (0..10_000).map(|s| sc.fires(s)).collect();
        assert_eq!(a, b, "replay with the same seed must sample the same set");
        let hits = a.iter().filter(|&&x| x).count();
        // 1-in-8 over 10k draws: a loose 3σ-ish band around 1250
        assert!((900..1600).contains(&hits), "hit rate off: {hits}/10000");
        // a different seed samples a different set
        let other = ShadowConfig { seed: 43, rate: 8 };
        let c: Vec<bool> = (0..10_000).map(|s| other.fires(s)).collect();
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn shadow_rate_edges() {
        let off = ShadowConfig { seed: 7, rate: 0 };
        assert!((0..100).all(|s| !off.fires(s)), "rate 0 disables sampling");
        let always = ShadowConfig { seed: 7, rate: 1 };
        assert!((0..100).all(|s| always.fires(s)), "rate 1 samples everything");
    }

    #[test]
    fn act_recording_accumulates_and_alarms() {
        let rec = Recorder::default();
        // calibrated [-1, 1]; values straddling it → half clipped
        rec.record_act(0, Some((-1.0, 1.0)), &[0.0, 0.5, 2.0, -3.0]);
        let snap = rec.snapshot();
        assert_eq!(snap.sites.len(), 1);
        let s = &snap.sites[0];
        assert_eq!(s.site, 0);
        assert_eq!(s.values, 4);
        assert_eq!(s.clipped, 2);
        assert!((s.clip_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(s.observed, Some((-3.0, 2.0)));
        // overshoot = (2-1) + (-1 - -3) = 3 over width 2 → 1500 pm
        assert_eq!(s.drift_max_permille, 1500);
        assert!(!snap.sites.is_empty());
        // keep clipping: the EWMA crosses the alarm threshold and latches
        for _ in 0..16 {
            rec.record_act(0, Some((-1.0, 1.0)), &[2.0, 2.0]);
        }
        let snap = rec.snapshot();
        assert!(snap.sites[0].alarm, "sustained clipping must latch the alarm");
        assert!(snap.drift_alarmed());
        // in-range traffic does not alarm
        let calm = Recorder::default();
        for _ in 0..100 {
            calm.record_act(1, Some((-1.0, 1.0)), &[0.1, -0.2, 0.9]);
        }
        let snap = calm.snapshot();
        assert!(!snap.sites[0].alarm);
        assert_eq!(snap.sites[0].clipped, 0);
        assert_eq!(snap.sites[0].drift_max_permille, 0);
    }

    #[test]
    fn uncalibrated_sites_observe_without_clipping() {
        let rec = Recorder::default();
        rec.record_act(3, None, &[-2.0, 5.0]);
        let snap = rec.snapshot();
        let s = &snap.sites[0];
        assert_eq!(s.calibrated, None);
        assert_eq!(s.observed, Some((-2.0, 5.0)));
        assert_eq!(s.clipped, 0);
        assert!(!s.alarm);
    }

    #[test]
    fn dispatch_and_ocs_telemetry_accumulate() {
        let rec = Recorder::default();
        rec.record_dispatch("encoder.0.attn.q.weight", [10, 80, 10]);
        rec.record_dispatch("encoder.0.attn.q.weight", [10, 80, 10]);
        rec.record_dispatch("pooler.weight", [0, 100, 0]);
        rec.record_ocs("encoder.0.attn.q.weight", 16, 0);
        rec.record_ocs("encoder.0.attn.q.weight", 16, 2);
        let snap = rec.snapshot();
        assert_eq!(snap.layers.len(), 2);
        // sorted by name: encoder.* before pooler.*
        let e = &snap.layers[0];
        assert_eq!(e.layer, "encoder.0.attn.q.weight");
        assert_eq!(e.occupancy, [20, 160, 20]);
        assert_eq!(e.dead_clusters, 0);
        assert_eq!(e.dispatches, 2);
        assert_eq!(e.ocs_calls, 2);
        assert_eq!(e.ocs_hits, 1);
        assert_eq!(e.outlier_cols, 2);
        assert_eq!(e.total_cols, 32);
        assert!((e.expansion_ratio() - 34.0 / 32.0).abs() < 1e-12);
        let p = &snap.layers[1];
        assert_eq!(p.layer, "pooler.weight");
        assert_eq!(p.dead_clusters, 2, "lower and upper clusters are dead");
    }

    #[test]
    fn shadow_stats_accumulate() {
        let rec = Recorder::default();
        rec.record_shadow(0.001, true);
        rec.record_shadow(0.003, false);
        let snap = rec.snapshot();
        assert_eq!(snap.shadow.samples, 2);
        assert_eq!(snap.shadow.top1_agree, 1);
        assert!((snap.shadow.agree_rate() - 0.5).abs() < 1e-12);
        assert!((snap.shadow.kl_mean_micro_nats - 2000.0).abs() < 1.0);
        assert_eq!(snap.shadow.kl_max_micro_nats, 3000);
    }

    #[test]
    fn logit_kl_properties() {
        let a = [1.0f32, 2.0, 3.0];
        assert_eq!(logit_kl(&a, &a), 0.0, "identical rows have zero divergence");
        // shifting logits by a constant leaves softmax (and KL) unchanged
        let b = [11.0f32, 12.0, 13.0];
        assert!(logit_kl(&a, &b) < 1e-12);
        let c = [3.0f32, 2.0, 1.0];
        assert!(logit_kl(&a, &c) > 0.1, "reversed preference must diverge");
        assert_eq!(logit_kl(&a, &[1.0, 2.0]), f64::INFINITY);
        assert_eq!(logit_kl(&a, &[1.0, f32::NAN, 3.0]), f64::INFINITY);
        assert_eq!(logit_kl(&[], &[]), f64::INFINITY);
    }

    #[test]
    fn render_is_byte_deterministic_and_sorted() {
        let rec = Recorder::default();
        rec.record_act(4, Some((-2.0, 2.0)), &[0.5, -0.25]);
        rec.record_act(0, Some((-1.0, 1.0)), &[1.5]);
        rec.record_dispatch("pooler.weight", [1, 2, 3]);
        rec.record_dispatch("classifier.weight", [4, 5, 6]);
        rec.record_shadow(0.002, true);
        let a = render(&rec.snapshot());
        let b = render(&rec.snapshot());
        assert_eq!(a, b, "repeated renders over unchanged state are identical");
        let site0 = a.find("site   0").expect("site 0 line");
        let site4 = a.find("site   4").expect("site 4 line");
        assert!(site0 < site4, "sites ascend numerically:\n{a}");
        let cls = a.find("layer classifier.weight").expect("classifier line");
        let pool = a.find("layer pooler.weight").expect("pooler line");
        assert!(cls < pool, "layers ascend lexicographically:\n{a}");
        assert!(a.contains("shadow: samples=1 top1_agree=1"), "{a}");
    }

    #[test]
    fn bench_rows_key_per_layer_and_shadow() {
        let rec = Recorder::default();
        rec.record_dispatch("encoder.0.ffn.in.weight", [5, 5, 5]);
        rec.record_shadow(0.001, true);
        let rows = bench_rows(&rec.snapshot(), "tiny", "int8");
        let benches: Vec<&str> = rows.iter().map(|r| r.bench.as_str()).collect();
        assert!(benches.contains(&"qhealth-encoder.0.ffn.in.weight"), "{benches:?}");
        assert!(benches.contains(&"qhealth-shadow"), "{benches:?}");
        for r in &rows {
            assert_eq!(r.shape, "tiny");
            assert_eq!(r.engine, "int8");
        }
        // no shadow samples → no shadow row
        let quiet = Recorder::default();
        quiet.record_dispatch("pooler.weight", [1, 1, 1]);
        let rows = bench_rows(&quiet.snapshot(), "tiny", "int8");
        assert!(rows.iter().all(|r| r.bench != "qhealth-shadow"), "{rows:?}");
    }

    #[test]
    fn empty_snapshot_is_empty() {
        let snap = Recorder::default().snapshot();
        assert!(snap.is_empty());
        assert!(!snap.drift_alarmed());
        assert_eq!(snap.shadow.agree_rate(), 1.0);
        assert!(bench_rows(&snap, "s", "e").is_empty());
    }
}
