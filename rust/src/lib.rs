//! # splitquant
//!
//! Production-oriented reproduction of *SplitQuant: Layer Splitting for
//! Low-Bit Neural Network Quantization* (Song & Lin, EDGE AI 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (build time): Pallas kernels (`python/compile/kernels/`) — fake
//!   quantization, split-dequant matmul, k-means assignment.
//! * **L2** (build time): JAX BERT-Tiny / CNN graphs lowered AOT to HLO text
//!   (`python/compile/model.py`, `aot.py` → `artifacts/`).
//! * **L3** (this crate): the runtime system. Rust owns parameter storage,
//!   training orchestration, the SplitQuant transform (k-means layer
//!   splitting), the post-training-quantization engine, baselines, the
//!   pure-Rust quantized-inference executor, the parallel kernel engine
//!   ([`parallel`]: persistent worker pool + cache-blocked kernels), the
//!   shard-paged model store ([`shardstore`]: serve models larger than RAM
//!   under a residency byte budget), the sensitivity-guided mixed-precision
//!   autotuner ([`autotune`]: per-layer bit allocation under a packed-byte
//!   budget), the PJRT runtime bridge and a batched serving coordinator.
//!   Python never runs on the request path.
//!
//! The public API is organized by subsystem; see `DESIGN.md` for the
//! paper → module map and `EXPERIMENTS.md` for reproduced results.

pub mod analysis;
pub mod autotune;
pub mod baselines;
pub mod clustering;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod model;
pub mod parallel;
pub mod qhealth;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod shardstore;
pub mod splitquant;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod util;

pub use error::{Error, Result};
