//! Outlier Channel Splitting (OCS), Zhao et al. ICML 2019 — the paper's
//! closest related work ([16]).
//!
//! OCS duplicates the channel containing the largest-magnitude weight and
//! halves both copies, shrinking the tensor's value range without dropping
//! the outlier (the consumer sums the duplicated outputs, so the function is
//! preserved — the same function-preserving trick family as SplitQuant, but
//! channel-granular and magnitude-focused).
//!
//! For PTQ *accuracy* evaluation we use the standard fake-quant emulation:
//! expand → quantize with the expanded tensor's range → fold the duplicates
//! back (`w ← 2·dq(q(w/2))` for split channels). This matches how the OCS
//! paper evaluates weight quantization without changing the network graph.

use crate::error::Result;
use crate::quant::{QConfig, QParams};
use crate::tensor::Tensor;

/// Result of the OCS transform on one tensor.
#[derive(Debug, Clone)]
pub struct OcsResult {
    /// Fake-quantized tensor with duplicates folded back (evaluation form).
    pub fake_quant: Tensor,
    /// How many channels were split.
    pub channels_split: usize,
    /// Channel count after expansion.
    pub expanded_channels: usize,
}

/// Apply OCS along the trailing axis (out-channels of an (in, out) linear
/// weight). `expand_ratio` is the fraction of extra channels to create
/// (OCS paper uses 1–5 %; each split halves the current max-|w| channel).
pub fn ocs_fake_quant(t: &Tensor, cfg: &QConfig, expand_ratio: f64) -> Result<OcsResult> {
    let (rows, cols) = t.as_2d();
    let n_extra = ((cols as f64 * expand_ratio).ceil() as usize).max(1);

    // per-original-channel max |w|
    let col_absmax: Vec<f32> = (0..cols)
        .map(|c| (0..rows).fold(0.0f32, |m, r| m.max(t.data()[r * cols + c].abs())))
        .collect();

    // expanded channels as (origin, fraction); copy value = fraction · column.
    // Each split halves the currently-largest copy and duplicates it, so an
    // original channel ends up represented by copies whose fractions sum to 1
    // (e.g. two splits can give {1/2, 1/4, 1/4}).
    let mut copies: Vec<(usize, f32)> = (0..cols).map(|c| (c, 1.0f32)).collect();
    for _ in 0..n_extra {
        let (ci, _) = copies
            .iter()
            .enumerate()
            .map(|(i, &(o, f))| (i, f * col_absmax[o]))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        copies[ci].1 *= 0.5;
        let dup = copies[ci];
        copies.push(dup);
    }

    // range over the EXPANDED tensor (this is where OCS wins: the halved
    // outlier no longer stretches the range)
    let mut all = Vec::with_capacity(copies.len() * rows);
    for &(o, f) in &copies {
        for r in 0..rows {
            all.push(t.data()[r * cols + o] * f);
        }
    }
    let (lo, hi) = cfg.observer.range(&all, cfg.bits)?;
    let p = if cfg.symmetric {
        QParams::symmetric_from_range(lo, hi, cfg.bits)
    } else {
        QParams::from_range(lo, hi, cfg.bits)
    };

    // fold back: channel c reconstructs as Σ_i dq(q(v·fᵢ)) over its copies —
    // exactly what the expanded graph computes when the consumer sums.
    let mut out = vec![0.0f32; rows * cols];
    let mut touched = vec![0usize; cols];
    for &(o, f) in &copies {
        touched[o] += 1;
        for r in 0..rows {
            out[r * cols + o] += p.fake(t.data()[r * cols + o] * f);
        }
    }
    Ok(OcsResult {
        fake_quant: Tensor::new(t.shape(), out).unwrap(),
        channels_split: touched.iter().filter(|&&k| k > 1).count(),
        expanded_channels: cols + n_extra,
    })
}

/// Store-level OCS baseline over the quantizable set (rank-2+ tensors only;
/// vectors fall back to plain quantization). Thin wrapper over a single
/// [`crate::quant::pipeline::OcsPass`] pipeline; the returned eval store is
/// copy-on-write shared with `store`.
pub fn quantize_store_ocs(
    store: &crate::model::params::ParamStore,
    quantizable: &[String],
    cfg: &QConfig,
    expand_ratio: f64,
) -> crate::error::Result<crate::model::params::ParamStore> {
    let pass = crate::quant::pipeline::OcsPass::new(*cfg, expand_ratio)
        .quantizable(quantizable.to_vec());
    let artifact = crate::quant::pipeline::QuantPipeline::new().pass(pass).run(store)?;
    Ok(artifact.eval)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn weight_with_outlier_channel(rows: usize, cols: usize, outlier: f32) -> Tensor {
        let mut rng = Rng::new(0);
        let mut t = Tensor::randn(&[rows, cols], 0.0, 0.1, &mut rng);
        // put the outlier in channel 0
        t.data_mut()[0] = outlier;
        t
    }

    #[test]
    fn ocs_beats_plain_quant_with_channel_outlier() {
        let t = weight_with_outlier_channel(64, 32, 8.0);
        let cfg = QConfig::baseline(4);
        let plain = crate::quant::qtensor::fake_quant_tensor(&t, &cfg).unwrap();
        let ocs = ocs_fake_quant(&t, &cfg, 0.10).unwrap();
        let mse = |a: &Tensor| -> f64 {
            a.data()
                .iter()
                .zip(t.data())
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum()
        };
        assert!(
            mse(&ocs.fake_quant) < mse(&plain),
            "ocs {} vs plain {}",
            mse(&ocs.fake_quant),
            mse(&plain)
        );
        assert!(ocs.channels_split >= 1);
    }

    #[test]
    fn ocs_preserves_function_at_high_bits() {
        // INT8 with mild expansion: reconstruction ~ exact
        let t = weight_with_outlier_channel(16, 8, 2.0);
        let r = ocs_fake_quant(&t, &QConfig::baseline(8), 0.25).unwrap();
        assert!(t.max_abs_diff(&r.fake_quant) < 0.05);
    }

    #[test]
    fn repeated_split_halves_repeatedly() {
        // with many splits allowed, the same outlier channel is halved again
        let t = weight_with_outlier_channel(4, 2, 100.0);
        let r = ocs_fake_quant(&t, &QConfig::baseline(2), 2.0).unwrap(); // 4 extra
        assert_eq!(r.expanded_channels, 2 + 4);
        assert_eq!(r.channels_split, 1, "all splits should hit the outlier channel");
    }

    #[test]
    fn expansion_accounting() {
        let t = weight_with_outlier_channel(8, 10, 5.0);
        let r = ocs_fake_quant(&t, &QConfig::baseline(4), 0.2).unwrap();
        assert_eq!(r.expanded_channels, 12);
    }
}
