//! Baseline PTQ methods SplitQuant is compared against:
//!
//! * [`quantize_store_baseline`] — plain per-tensor (or per-channel) affine
//!   quantization under any [`QConfig`]: min-max (the paper's "Baseline"
//!   column), percentile clipping (§1's de-facto outlier treatment) or MSE
//!   search.
//! * [`ocs`] — Outlier Channel Splitting (Zhao et al., ICML 2019; paper
//!   related work [16]).

pub mod ocs;

use std::collections::BTreeMap;

use crate::error::Result;
use crate::model::params::ParamStore;
use crate::quant::pipeline::{BaselinePass, QuantPipeline};
use crate::quant::{QConfig, QTensor};

/// Quantize every `quantizable` parameter with one shared [`QConfig`].
/// Returns the dequantized eval store (copy-on-write shared with `store`)
/// and the packed tensors. Thin wrapper over a single
/// [`BaselinePass`] pipeline.
pub fn quantize_store_baseline(
    store: &ParamStore,
    quantizable: &[String],
    cfg: &QConfig,
) -> Result<(ParamStore, BTreeMap<String, QTensor>)> {
    let artifact = QuantPipeline::new()
        .pass(BaselinePass::new(*cfg).quantizable(quantizable.to_vec()))
        .run(store)?;
    Ok((artifact.eval, artifact.tensors))
}

/// Packed byte total of a quantized tensor map.
pub fn quantized_bytes(tensors: &BTreeMap<String, QTensor>) -> usize {
    tensors.values().map(|q| q.byte_size()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;
    use crate::splitquant::default_quantizable;
    use crate::util::rng::Rng;

    #[test]
    fn baseline_store_quantization() {
        let cfg = BertConfig {
            vocab_size: 32,
            hidden: 8,
            layers: 1,
            heads: 2,
            ffn: 16,
            max_len: 8,
            num_classes: 2,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let quantizable = default_quantizable(&store);
        let (eval, tensors) =
            quantize_store_baseline(&store, &quantizable, &QConfig::baseline(8)).unwrap();
        eval.check_order(&cfg.param_order()).unwrap();
        assert_eq!(tensors.len(), quantizable.len());
        // INT8 reconstruction is tight
        for name in &quantizable {
            let d = store.get(name).unwrap().max_abs_diff(eval.get(name).unwrap());
            let step = tensors[name].params()[0].step();
            assert!(d <= step, "{name}: {d} vs step {step}");
        }
    }

    #[test]
    fn percentile_baseline_clips() {
        // a huge outlier shrinks the percentile range; the outlier itself is
        // then badly reconstructed (the paper's "lost signal")
        let mut data = vec![0.0f32; 999];
        let mut rng = Rng::new(1);
        for v in &mut data {
            *v = rng.normal_f32(0.0, 1.0);
        }
        data.push(1000.0);
        let order = vec![("w.weight".to_string(), vec![1000usize])];
        let mut store = ParamStore::zeros(&order);
        store.set("w.weight", crate::tensor::Tensor::new(&[1000], data).unwrap()).unwrap();
        let names = vec!["w.weight".to_string()];
        let (eval, _) =
            quantize_store_baseline(&store, &names, &QConfig::percentile(4, 99.0)).unwrap();
        let rec = eval.get("w.weight").unwrap().data()[999];
        assert!(rec < 10.0, "outlier should be crushed by clipping, got {rec}");
    }
}
