//! Evaluation harness: PTQ method preparation + accuracy measurement through
//! either executor (pure-Rust or PJRT).

use crate::baselines;
use crate::data::batch::TextBatch;
use crate::error::Result;
use crate::model::bert::{argmax_rows, BertModel};
use crate::model::config::BertConfig;
use crate::model::params::ParamStore;
use crate::quant::pipeline::{BaselinePass, OcsPass, QuantPipeline, SplitQuantPass};
use crate::quant::QConfig;
use crate::runtime::literal::Value;
use crate::runtime::Runtime;
use crate::splitquant::{self, ActQuantParams, SplitQuantConfig};

/// Weight-quantization method under evaluation (one Table-1 cell).
#[derive(Debug, Clone, Copy)]
pub enum WeightMethod {
    /// FP32 reference (no quantization).
    None,
    /// Per-tensor affine PTQ under a [`QConfig`] (baseline / percentile / mse).
    Baseline(QConfig),
    /// SplitQuant (the paper).
    SplitQuant(SplitQuantConfig),
    /// Outlier channel splitting (related work [16]).
    Ocs(QConfig, f64),
}

impl WeightMethod {
    pub fn label(&self) -> String {
        match self {
            WeightMethod::None => "FP32".into(),
            WeightMethod::Baseline(c) => format!("baseline {}", c.label()),
            WeightMethod::SplitQuant(c) => format!("splitquant INT{} k={}", c.bits, c.k),
            WeightMethod::Ocs(c, r) => format!("ocs {} expand={r}", c.label()),
        }
    }
}

/// Apply a weight PTQ method, returning the eval store (dequantized weights,
/// copy-on-write shared with `store`) and the packed size in bytes when
/// applicable. Each method is a one-pass [`QuantPipeline`]; the passes all
/// default to [`splitquant::default_quantizable`], so the Table-1 methods
/// stay strictly comparable.
pub fn prepare_store(
    store: &ParamStore,
    method: &WeightMethod,
) -> Result<(ParamStore, Option<usize>)> {
    match method {
        WeightMethod::None => Ok((store.share(), None)),
        WeightMethod::Baseline(cfg) => {
            let a = QuantPipeline::new().pass(BaselinePass::new(*cfg)).run(store)?;
            let bytes = baselines::quantized_bytes(&a.tensors);
            Ok((a.eval, Some(bytes)))
        }
        WeightMethod::SplitQuant(cfg) => {
            let a = QuantPipeline::new()
                .pass(SplitQuantPass::with_config(*cfg))
                .run(store)?;
            let bytes = baselines::quantized_bytes(&a.tensors);
            Ok((a.eval, Some(bytes)))
        }
        WeightMethod::Ocs(cfg, ratio) => {
            let a = QuantPipeline::new().pass(OcsPass::new(*cfg, *ratio)).run(store)?;
            Ok((a.eval, None))
        }
    }
}

/// Accuracy through the pure-Rust executor. `act` optionally applies
/// activation fake-quant at every site (calibrated [`ActQuantParams`]).
pub fn accuracy_rust(
    cfg: &BertConfig,
    store: &ParamStore,
    batches: &[TextBatch],
    n: usize,
    act: Option<&ActQuantParams>,
) -> Result<f64> {
    let model = BertModel::new(cfg.clone(), store.share())?;
    let mut hits = 0usize;
    let mut seen = 0usize;
    for b in batches {
        let logits = match act {
            None => model.forward(&b.ids, &b.mask),
            Some(a) => {
                let mut hook = a.hook(cfg);
                model.forward_hooked(&b.ids, &b.mask, Some(&mut hook))
            }
        };
        let preds = argmax_rows(&logits);
        for (p, l) in preds.iter().zip(b.labels.data()) {
            if seen >= n {
                break;
            }
            hits += usize::from(p == l);
            seen += 1;
        }
    }
    Ok(hits as f64 / seen.max(1) as f64)
}

/// Per-batch argmax predictions through the pure-Rust executor, stopping
/// after the batch that covers the `n`-th example (no dead forwards past
/// the cap). Precompute these once when scoring several candidates against
/// the same reference ([`agreement_with_reference`]).
pub fn predictions_rust(
    cfg: &BertConfig,
    store: &ParamStore,
    batches: &[TextBatch],
    n: usize,
) -> Result<Vec<Vec<i32>>> {
    let m = BertModel::new(cfg.clone(), store.share())?;
    let mut out = Vec::new();
    let mut seen = 0usize;
    for b in batches {
        if seen >= n {
            break;
        }
        let p = argmax_rows(&m.forward(&b.ids, &b.mask));
        seen += p.len();
        out.push(p);
    }
    Ok(out)
}

/// Top-1 agreement of `candidate` against precomputed reference predictions
/// ([`predictions_rust`]) over the first `n` examples.
pub fn agreement_with_reference(
    cfg: &BertConfig,
    reference_preds: &[Vec<i32>],
    candidate: &ParamStore,
    batches: &[TextBatch],
    n: usize,
) -> Result<f64> {
    let cm = BertModel::new(cfg.clone(), candidate.share())?;
    let mut hits = 0usize;
    let mut seen = 0usize;
    for (b, rp) in batches.iter().zip(reference_preds) {
        if seen >= n {
            break;
        }
        let cp = argmax_rows(&cm.forward(&b.ids, &b.mask));
        for (r, c) in rp.iter().zip(&cp) {
            if seen >= n {
                break;
            }
            hits += usize::from(r == c);
            seen += 1;
        }
    }
    Ok(hits as f64 / seen.max(1) as f64)
}

/// Top-1 agreement between two weight sets through the pure-Rust executor:
/// the fraction of the first `n` examples whose argmax under `candidate`
/// matches the one under `reference`. This is the fidelity figure the
/// mixed-precision autotuner ([`crate::autotune`]) optimizes for — unlike
/// task accuracy it is meaningful even for untrained or synthetic setups,
/// and for a trained checkpoint it lower-bounds the accuracy retained.
pub fn agreement_rust(
    cfg: &BertConfig,
    reference: &ParamStore,
    candidate: &ParamStore,
    batches: &[TextBatch],
    n: usize,
) -> Result<f64> {
    let refs = predictions_rust(cfg, reference, batches, n)?;
    agreement_with_reference(cfg, &refs, candidate, batches, n)
}

/// Top-1 agreement of the **integer deployment path** against precomputed
/// FP32 reference predictions ([`predictions_rust`]): the candidate packed
/// model executes through [`crate::model::qbert::QuantizedBert`] on the
/// [`crate::parallel::KernelKind::Int8`] engine — fused quantized weights,
/// activations quantized to 8 bits (calibrated `act` params when given,
/// per-call min–max otherwise). This is the int8-engine fidelity column the
/// kernel bench reports next to its throughput rows; without the `simd`
/// feature the engine degrades to the f32 path and the figure measures
/// weight quantization alone.
pub fn agreement_int8(
    cfg: &BertConfig,
    reference_preds: &[Vec<i32>],
    store: &ParamStore,
    qm: &splitquant::QuantizedModel,
    batches: &[TextBatch],
    n: usize,
    act: Option<&ActQuantParams>,
) -> Result<f64> {
    let mut qbert = crate::model::qbert::QuantizedBert::new(cfg.clone(), store, qm)?;
    qbert.set_kernel(crate::parallel::KernelKind::Int8);
    if let Some(a) = act {
        qbert.set_act_params(a.clone());
    }
    let mut hits = 0usize;
    let mut seen = 0usize;
    for (b, rp) in batches.iter().zip(reference_preds) {
        if seen >= n {
            break;
        }
        let cp = qbert.predict(&b.ids, &b.mask)?;
        for (r, c) in rp.iter().zip(&cp) {
            if seen >= n {
                break;
            }
            hits += usize::from(r == c);
            seen += 1;
        }
    }
    Ok(hits as f64 / seen.max(1) as f64)
}

/// Accuracy through a PJRT forward executable (`bert_fwd_b{B}`); batches must
/// match the executable's batch size.
pub fn accuracy_pjrt(
    rt: &Runtime,
    exe_name: &str,
    store: &ParamStore,
    batches: &[TextBatch],
    n: usize,
) -> Result<f64> {
    let exe = rt.load(exe_name)?;
    let mut hits = 0usize;
    let mut seen = 0usize;
    for b in batches {
        let mut inputs: Vec<Value> =
            store.flat_tensors().map(|t| Value::F32(t.clone())).collect();
        inputs.push(Value::I32(b.ids.clone()));
        inputs.push(Value::F32(b.mask.clone()));
        let logits = exe.run_f32(&inputs)?;
        let preds = argmax_rows(&logits);
        for (p, l) in preds.iter().zip(b.labels.data()) {
            if seen >= n {
                break;
            }
            hits += usize::from(p == l);
            seen += 1;
        }
    }
    Ok(hits as f64 / seen.max(1) as f64)
}

/// Accuracy through the AOT **act-quant** executable, exercising the L1
/// Pallas fake-quant kernel on the request path (ablation A3).
pub fn accuracy_pjrt_actquant(
    rt: &Runtime,
    store: &ParamStore,
    batches: &[TextBatch],
    n: usize,
    act: &ActQuantParams,
) -> Result<f64> {
    let batch = batches
        .first()
        .map(|b| b.ids.shape()[0])
        .ok_or_else(|| crate::error::Error::Runtime("no batches".into()))?;
    let exe = rt.load(&format!("bert_fwd_actquant_b{batch}"))?;
    let (scales, zps) = act.to_arrays();
    let (qmin, qmax) = crate::quant::qrange(act.bits);
    let mut hits = 0usize;
    let mut seen = 0usize;
    for b in batches {
        let mut inputs: Vec<Value> =
            store.flat_tensors().map(|t| Value::F32(t.clone())).collect();
        inputs.push(Value::I32(b.ids.clone()));
        inputs.push(Value::F32(b.mask.clone()));
        inputs.push(Value::F32(scales.clone()));
        inputs.push(Value::F32(zps.clone()));
        inputs.push(Value::F32(crate::tensor::Tensor::scalar(qmin as f32)));
        inputs.push(Value::F32(crate::tensor::Tensor::scalar(qmax as f32)));
        let logits = exe.run_f32(&inputs)?;
        let preds = argmax_rows(&logits);
        for (p, l) in preds.iter().zip(b.labels.data()) {
            if seen >= n {
                break;
            }
            hits += usize::from(p == l);
            seen += 1;
        }
    }
    Ok(hits as f64 / seen.max(1) as f64)
}

/// Calibrate activation ranges by running FP32 forwards over `batches`
/// through the pure-Rust executor.
pub fn calibrate(
    cfg: &BertConfig,
    store: &ParamStore,
    batches: &[TextBatch],
) -> Result<splitquant::ActCalibrator> {
    let model = BertModel::new(cfg.clone(), store.share())?;
    let mut cal = splitquant::ActCalibrator::new(cfg);
    for b in batches {
        let mut hook = cal.hook();
        model.forward_hooked(&b.ids, &b.mask, Some(&mut hook));
    }
    Ok(cal)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{emotion, pad_to_batches, HashTokenizer};
    use crate::util::rng::Rng;

    fn tiny_setup() -> (BertConfig, ParamStore, Vec<TextBatch>, usize) {
        let cfg = BertConfig {
            vocab_size: 512,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 16,
            num_classes: 6,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let (_, test) = emotion::load_small(0, 10, 60);
        let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
        let (batches, n) = pad_to_batches(&test, &tok, 16);
        (cfg, store, batches, n)
    }

    #[test]
    fn untrained_accuracy_near_chance() {
        let (cfg, store, batches, n) = tiny_setup();
        let acc = accuracy_rust(&cfg, &store, &batches, n, None).unwrap();
        assert!(acc < 0.55, "untrained acc {acc}");
    }

    #[test]
    fn prepare_store_all_methods_run() {
        let (_cfg, store, _, _) = tiny_setup();
        for m in [
            WeightMethod::None,
            WeightMethod::Baseline(QConfig::baseline(4)),
            WeightMethod::Baseline(QConfig::percentile(4, 99.0)),
            WeightMethod::SplitQuant(SplitQuantConfig::new(4)),
            WeightMethod::Ocs(QConfig::baseline(4), 0.05),
        ] {
            let (eval, bytes) = prepare_store(&store, &m).unwrap();
            assert_eq!(eval.len(), store.len(), "{}", m.label());
            if matches!(m, WeightMethod::Baseline(_) | WeightMethod::SplitQuant(_)) {
                assert!(bytes.unwrap() > 0);
            }
        }
    }

    #[test]
    fn splitquant_bytes_larger_than_baseline_but_bounded() {
        // paper §6: split adds the cid plane — size grows, but far less than
        // the naive 3× (we never materialize zeros)
        let (_cfg, store, _, _) = tiny_setup();
        let (_, b1) =
            prepare_store(&store, &WeightMethod::Baseline(QConfig::baseline(2))).unwrap();
        let (_, b2) =
            prepare_store(&store, &WeightMethod::SplitQuant(SplitQuantConfig::new(2))).unwrap();
        let (b1, b2) = (b1.unwrap(), b2.unwrap());
        assert!(b2 > b1, "split {b2} should exceed baseline {b1}");
        assert!(b2 < b1 * 3, "split {b2} must stay under 3x baseline {b1}");
    }

    #[test]
    fn agreement_is_one_for_identical_stores_and_degrades_with_bits() {
        let (cfg, store, batches, n) = tiny_setup();
        let same = agreement_rust(&cfg, &store, &store, &batches, n).unwrap();
        assert_eq!(same, 1.0);
        let (int8, _) =
            prepare_store(&store, &WeightMethod::SplitQuant(SplitQuantConfig::new(8))).unwrap();
        let (int2, _) =
            prepare_store(&store, &WeightMethod::SplitQuant(SplitQuantConfig::new(2))).unwrap();
        let a8 = agreement_rust(&cfg, &store, &int8, &batches, n).unwrap();
        let a2 = agreement_rust(&cfg, &store, &int2, &batches, n).unwrap();
        assert!(a8 >= a2, "INT8 fidelity {a8} below INT2 {a2}");
        assert!(a8 > 0.5, "INT8 should track the FP32 argmax closely ({a8})");
    }

    #[test]
    fn int8_engine_agreement_tracks_the_f32_reference() {
        let (cfg, store, batches, n) = tiny_setup();
        let quantizable = splitquant::default_quantizable(&store);
        let (_, qm) = splitquant::quantize_store(&store, &quantizable, &SplitQuantConfig::new(8))
            .unwrap();
        let refs = predictions_rust(&cfg, &store, &batches, n).unwrap();
        let a = agreement_int8(&cfg, &refs, &store, &qm, &batches, n, None).unwrap();
        assert!(a > 0.5, "int8 engine agreement {a}");
    }

    #[test]
    fn calibration_then_act_quant_eval() {
        let (cfg, store, batches, n) = tiny_setup();
        let cal = calibrate(&cfg, &store, &batches[..1]).unwrap();
        let act = cal.to_params(8, crate::splitquant::ActQuantMode::Split);
        let acc_fp = accuracy_rust(&cfg, &store, &batches, n, None).unwrap();
        let acc_a8 = accuracy_rust(&cfg, &store, &batches, n, Some(&act)).unwrap();
        // INT8 activations barely move an untrained model's accuracy
        assert!((acc_fp - acc_a8).abs() < 0.35, "fp {acc_fp} vs a8 {acc_a8}");
    }
}
