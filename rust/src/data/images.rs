//! Synthetic 16×16 grayscale image workload for the CNN / conv-splitting
//! path (Figure 3). Four structurally distinct classes plus noise.

use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;

pub const IMAGE: usize = 16;
pub const NUM_CLASSES: usize = 4;

/// A labelled image dataset in NCHW layout.
#[derive(Debug, Clone)]
pub struct ImageDataset {
    /// f32[N, 1, 16, 16]
    pub images: Tensor,
    /// i32[N]
    pub labels: IntTensor,
}

impl ImageDataset {
    pub fn len(&self) -> usize {
        self.images.shape()[0]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Slice a contiguous batch `[start, start+b)`, wrapping around.
    pub fn batch(&self, start: usize, b: usize) -> (Tensor, IntTensor) {
        let n = self.len();
        let hw = IMAGE * IMAGE;
        let mut img = Vec::with_capacity(b * hw);
        let mut lab = Vec::with_capacity(b);
        for i in 0..b {
            let idx = (start + i) % n;
            img.extend_from_slice(&self.images.data()[idx * hw..(idx + 1) * hw]);
            lab.push(self.labels.data()[idx]);
        }
        (
            Tensor::new(&[b, 1, IMAGE, IMAGE], img).unwrap(),
            IntTensor::new(&[b], lab).unwrap(),
        )
    }
}

fn draw(class: usize, rng: &mut Rng) -> Vec<f32> {
    let mut px = vec![0.0f32; IMAGE * IMAGE];
    match class {
        0 => {
            // horizontal stripes, random phase/period
            let period = rng.range(2, 5);
            let phase = rng.below(period);
            for y in 0..IMAGE {
                let v = if (y + phase) % period < period / 2 + 1 { 1.0 } else { -1.0 };
                for x in 0..IMAGE {
                    px[y * IMAGE + x] = v;
                }
            }
        }
        1 => {
            // vertical stripes
            let period = rng.range(2, 5);
            let phase = rng.below(period);
            for y in 0..IMAGE {
                for x in 0..IMAGE {
                    px[y * IMAGE + x] = if (x + phase) % period < period / 2 + 1 { 1.0 } else { -1.0 };
                }
            }
        }
        2 => {
            // checkerboard
            let cell = rng.range(2, 4);
            for y in 0..IMAGE {
                for x in 0..IMAGE {
                    px[y * IMAGE + x] = if (x / cell + y / cell) % 2 == 0 { 1.0 } else { -1.0 };
                }
            }
        }
        _ => {
            // centered gaussian blob with random center/width
            let cx = rng.range(4, 12) as f32;
            let cy = rng.range(4, 12) as f32;
            let s = rng.range_f64(2.0, 4.0) as f32;
            for y in 0..IMAGE {
                for x in 0..IMAGE {
                    let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                    px[y * IMAGE + x] = 2.0 * (-d2 / (2.0 * s * s)).exp() - 0.5;
                }
            }
        }
    }
    // additive noise
    for p in &mut px {
        *p += rng.normal_f32(0.0, 0.25);
    }
    px
}

/// Generate `n` labelled images, classes uniform.
pub fn generate(n: usize, rng: &mut Rng) -> ImageDataset {
    let mut img = Vec::with_capacity(n * IMAGE * IMAGE);
    let mut lab = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(NUM_CLASSES);
        img.extend(draw(c, rng));
        lab.push(c as i32);
    }
    ImageDataset {
        images: Tensor::new(&[n, 1, IMAGE, IMAGE], img).unwrap(),
        labels: IntTensor::new(&[n], lab).unwrap(),
    }
}

/// Standard (train, test) split.
pub fn load(seed: u64, train_n: usize, test_n: usize) -> (ImageDataset, ImageDataset) {
    let mut root = Rng::new(seed ^ 0x1111_2222);
    let mut tr = root.fork(1);
    let mut te = root.fork(2);
    (generate(train_n, &mut tr), generate(test_n, &mut te))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let (tr, te) = load(0, 100, 40);
        assert_eq!(tr.images.shape(), &[100, 1, 16, 16]);
        assert_eq!(te.len(), 40);
        let (tr2, _) = load(0, 100, 40);
        assert_eq!(tr.images.data(), tr2.images.data());
    }

    #[test]
    fn classes_distinguishable_by_simple_statistic() {
        // row-variance separates horizontal stripes from vertical stripes
        let mut rng = Rng::new(1);
        let h = draw(0, &mut rng);
        let v = draw(1, &mut rng);
        let row_var = |px: &[f32]| -> f32 {
            (0..IMAGE)
                .map(|y| {
                    let row = &px[y * IMAGE..(y + 1) * IMAGE];
                    let m: f32 = row.iter().sum::<f32>() / IMAGE as f32;
                    row.iter().map(|&p| (p - m) * (p - m)).sum::<f32>()
                })
                .sum()
        };
        assert!(row_var(&h) < row_var(&v), "{} vs {}", row_var(&h), row_var(&v));
    }

    #[test]
    fn batch_wraps() {
        let (tr, _) = load(0, 10, 1);
        let (img, lab) = tr.batch(8, 4); // wraps past the end
        assert_eq!(img.shape(), &[4, 1, 16, 16]);
        assert_eq!(lab.data()[2], tr.labels.data()[0]);
    }
}
