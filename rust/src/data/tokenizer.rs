//! Deterministic hash tokenizer.
//!
//! A fixed-vocabulary, training-free tokenizer: each whitespace-separated,
//! lowercased word maps to `4 + (fnv1a(word) mod (V−4))`. Ids 0–3 are
//! reserved (PAD/CLS/SEP/UNK). Collisions are possible and harmless for the
//! synthetic corpora (the class-signal words are chosen collision-free at
//! construction time — asserted in tests).

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
pub const UNK: i32 = 3;
pub const RESERVED: i32 = 4;

/// Stateless hash tokenizer with BERT-style special tokens.
#[derive(Debug, Clone)]
pub struct HashTokenizer {
    pub vocab_size: usize,
    pub max_len: usize,
}

impl HashTokenizer {
    pub fn new(vocab_size: usize, max_len: usize) -> Self {
        assert!(vocab_size > RESERVED as usize + 1);
        assert!(max_len >= 3, "need room for CLS + token + SEP");
        HashTokenizer { vocab_size, max_len }
    }

    /// FNV-1a 64-bit.
    fn hash(word: &str) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in word.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Id of a single word.
    pub fn word_id(&self, word: &str) -> i32 {
        let lower = word.to_lowercase();
        let span = (self.vocab_size - RESERVED as usize) as u64;
        RESERVED + (Self::hash(&lower) % span) as i32
    }

    /// Encode text to `[CLS] w1 … wn [SEP] PAD…` with an attention mask.
    /// Truncates to `max_len`; returns (ids, mask) both of length `max_len`.
    pub fn encode(&self, text: &str) -> (Vec<i32>, Vec<f32>) {
        let mut ids = Vec::with_capacity(self.max_len);
        ids.push(CLS);
        for w in text.split_whitespace() {
            if ids.len() >= self.max_len - 1 {
                break;
            }
            ids.push(self.word_id(w));
        }
        ids.push(SEP);
        let used = ids.len();
        let mut mask = vec![1.0f32; used];
        ids.resize(self.max_len, PAD);
        mask.resize(self.max_len, 0.0);
        (ids, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let t = HashTokenizer::new(8192, 64);
        let a = t.word_id("hello");
        assert_eq!(a, t.word_id("HELLO"), "case-insensitive");
        assert!(a >= RESERVED && (a as usize) < 8192);
    }

    #[test]
    fn encode_structure() {
        let t = HashTokenizer::new(8192, 8);
        let (ids, mask) = t.encode("a b c");
        assert_eq!(ids.len(), 8);
        assert_eq!(ids[0], CLS);
        assert_eq!(ids[4], SEP);
        assert_eq!(ids[5], PAD);
        assert_eq!(mask, vec![1., 1., 1., 1., 1., 0., 0., 0.]);
    }

    #[test]
    fn truncation() {
        let t = HashTokenizer::new(8192, 6);
        let long: String = (0..50).map(|i| format!("w{i} ")).collect();
        let (ids, mask) = t.encode(&long);
        assert_eq!(ids.len(), 6);
        assert_eq!(ids[5], SEP);
        assert!(mask.iter().all(|&m| m == 1.0));
    }

    #[test]
    fn empty_text() {
        let t = HashTokenizer::new(8192, 6);
        let (ids, mask) = t.encode("");
        assert_eq!(&ids[..2], &[CLS, SEP]);
        assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), 2);
    }

    #[test]
    fn distinct_words_mostly_distinct_ids() {
        let t = HashTokenizer::new(8192, 64);
        let ids: std::collections::HashSet<i32> =
            (0..500).map(|i| t.word_id(&format!("word{i}"))).collect();
        assert!(ids.len() > 480, "too many collisions: {}", ids.len());
    }
}
