//! Synthetic SMS spam corpus (stand-in for the UCI SMS Spam Collection,
//! Almeida et al. 2011: 5574 messages, ~13.4 % spam, no held-out split — the
//! paper evaluates on the full set it fine-tuned on, and we replicate that
//! protocol).

use crate::util::rng::Rng;

use super::synth_text::{generate, CorpusSpec, TextDataset};

pub const NUM_CLASSES: usize = 2;
pub const SIZE: usize = 5_574;
pub const SPAM_PRIOR: f64 = 0.134;

const HAM: &[&str] = &[
    "ok", "lol", "gonna", "later", "tonight", "meet", "dinner", "sorry", "thanks", "yeah",
    "cool", "home", "soon", "miss", "see", "tomorrow", "bus", "class", "sleep", "movie",
    "mom", "bro", "dude", "haha", "hey", "pick", "waiting", "done", "coming", "leave",
];
const SPAM: &[&str] = &[
    "free", "winner", "won", "prize", "claim", "urgent", "cash", "txt", "text", "call",
    "now", "mobile", "offer", "guaranteed", "award", "bonus", "click", "subscribe",
    "ringtone", "voucher", "credit", "deal", "limited", "congratulations", "selected",
    "150p", "18+", "sms", "win", "gift",
];

fn spec() -> CorpusSpec<'static> {
    const WORDS: [&[&str]; 2] = [HAM, SPAM];
    CorpusSpec {
        name: "sms-spam",
        class_names: &["ham", "spam"],
        class_words: &WORDS,
        signal: 0.18,
        len_range: (5, 24),
        filler: 1200,
        priors: &[1.0 - SPAM_PRIOR, SPAM_PRIOR],
        label_noise: 0.015,
    }
}

/// The full 5574-message corpus (used for both fine-tuning and evaluation,
/// matching the paper's protocol for this dataset).
pub fn load(seed: u64) -> TextDataset {
    let mut rng = Rng::new(seed ^ 0x5A5A_1234);
    let mut d = generate(&spec(), SIZE, &mut rng);
    d.name = "sms-spam".into();
    d
}

/// Smaller corpus for tests.
pub fn load_small(seed: u64, n: usize) -> TextDataset {
    let mut rng = Rng::new(seed ^ 0x5A5A_1234);
    generate(&spec(), n, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_and_prior_match_uci() {
        let d = load(0);
        assert_eq!(d.len(), SIZE);
        let h = d.class_histogram();
        let spam_frac = h[1] as f64 / d.len() as f64;
        assert!((spam_frac - SPAM_PRIOR).abs() < 0.02, "spam fraction {spam_frac}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(load(3).texts, load(3).texts);
        assert_ne!(load(3).texts, load(4).texts);
    }

    #[test]
    fn spam_contains_spam_words() {
        let d = load(0);
        let mut hits = 0;
        let mut total = 0;
        for (t, &l) in d.texts.iter().zip(&d.labels) {
            if l == 1 {
                total += 1;
                if SPAM.iter().any(|w| t.split_whitespace().any(|x| x == *w)) {
                    hits += 1;
                }
            }
        }
        assert!(hits as f64 > total as f64 * 0.8, "{hits}/{total}");
    }
}
