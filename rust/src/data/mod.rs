//! Synthetic data substrates.
//!
//! The paper evaluates on DAIR.AI emotion recognition and the UCI SMS Spam
//! Collection; neither is reachable from this offline sandbox, so
//! [`emotion`] and [`spam`] generate synthetic equivalents with the same
//! cardinalities, class structure and evaluation protocol (see DESIGN.md §2
//! for the substitution argument). [`images`] generates the small vision
//! workload for the CNN / conv-splitting path.

pub mod batch;
pub mod emotion;
pub mod images;
pub mod spam;
pub mod synth_text;
pub mod tokenizer;
pub mod trace;

pub use batch::{pad_to_batches, TextBatch, TextBatcher};
pub use synth_text::TextDataset;
pub use tokenizer::HashTokenizer;
