//! Tokenized mini-batching for text datasets.

use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;

use super::synth_text::TextDataset;
use super::tokenizer::HashTokenizer;

/// One tokenized batch, ready to feed the BERT executables / executor.
#[derive(Debug, Clone)]
pub struct TextBatch {
    /// i32[B, L]
    pub ids: IntTensor,
    /// f32[B, L]
    pub mask: Tensor,
    /// i32[B]
    pub labels: IntTensor,
}

/// Pre-tokenized dataset + epoch shuffling, emitting fixed-size batches.
pub struct TextBatcher {
    ids: Vec<Vec<i32>>,
    masks: Vec<Vec<f32>>,
    labels: Vec<i32>,
    pub batch_size: usize,
    pub max_len: usize,
    order: Vec<usize>,
    cursor: usize,
}

impl TextBatcher {
    pub fn new(data: &TextDataset, tok: &HashTokenizer, batch_size: usize) -> Self {
        let mut ids = Vec::with_capacity(data.len());
        let mut masks = Vec::with_capacity(data.len());
        for t in &data.texts {
            let (i, m) = tok.encode(t);
            ids.push(i);
            masks.push(m);
        }
        TextBatcher {
            ids,
            masks,
            labels: data.labels.clone(),
            batch_size,
            max_len: tok.max_len,
            order: (0..data.len()).collect(),
            cursor: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Shuffle the visit order (call between epochs).
    pub fn shuffle(&mut self, rng: &mut Rng) {
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next batch, cycling (wrapping) over the dataset.
    pub fn next_batch(&mut self) -> TextBatch {
        let b = self.batch_size;
        let l = self.max_len;
        let mut ids = Vec::with_capacity(b * l);
        let mut mask = Vec::with_capacity(b * l);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let idx = self.order[self.cursor];
            self.cursor = (self.cursor + 1) % self.order.len();
            ids.extend_from_slice(&self.ids[idx]);
            mask.extend_from_slice(&self.masks[idx]);
            labels.push(self.labels[idx]);
        }
        TextBatch {
            ids: IntTensor::new(&[b, l], ids).unwrap(),
            mask: Tensor::new(&[b, l], mask).unwrap(),
            labels: IntTensor::new(&[b], labels).unwrap(),
        }
    }

    /// All batches covering the dataset once in order, padding the tail by
    /// wrapping; returns (batches, true sample count) for exact accuracy.
    pub fn epoch_batches(&mut self) -> (Vec<TextBatch>, usize) {
        let n = self.len();
        self.cursor = 0;
        self.order = (0..n).collect();
        let nb = n.div_ceil(self.batch_size);
        let mut out = Vec::with_capacity(nb);
        for _ in 0..nb {
            out.push(self.next_batch());
        }
        (out, n)
    }
}

/// Tokenize the whole dataset into eval batches of `batch_size` (tail wraps);
/// returns (batches, true sample count).
pub fn pad_to_batches(
    data: &TextDataset,
    tok: &HashTokenizer,
    batch_size: usize,
) -> (Vec<TextBatch>, usize) {
    let mut b = TextBatcher::new(data, tok, batch_size);
    b.epoch_batches()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::emotion;

    #[test]
    fn batch_shapes() {
        let (_, test) = emotion::load_small(0, 10, 50);
        let tok = HashTokenizer::new(8192, 64);
        let mut b = TextBatcher::new(&test, &tok, 8);
        let batch = b.next_batch();
        assert_eq!(batch.ids.shape(), &[8, 64]);
        assert_eq!(batch.mask.shape(), &[8, 64]);
        assert_eq!(batch.labels.shape(), &[8]);
    }

    #[test]
    fn epoch_covers_everything_once() {
        let (_, test) = emotion::load_small(0, 10, 21);
        let tok = HashTokenizer::new(8192, 64);
        let mut b = TextBatcher::new(&test, &tok, 8);
        let (batches, n) = b.epoch_batches();
        assert_eq!(n, 21);
        assert_eq!(batches.len(), 3); // 8 + 8 + 5(+3 wrapped)
        // first 21 labels across batches match the dataset order
        let flat: Vec<i32> = batches.iter().flat_map(|b| b.labels.data().to_vec()).collect();
        assert_eq!(&flat[..21], &test.labels[..]);
    }

    #[test]
    fn shuffle_changes_order_but_not_content() {
        let (_, test) = emotion::load_small(0, 10, 64);
        let tok = HashTokenizer::new(8192, 64);
        let mut b = TextBatcher::new(&test, &tok, 64);
        let before = b.next_batch();
        let mut rng = Rng::new(1);
        b.shuffle(&mut rng);
        let after = b.next_batch();
        assert_ne!(before.labels.data(), after.labels.data());
        let mut x = before.labels.data().to_vec();
        let mut y = after.labels.data().to_vec();
        x.sort();
        y.sort();
        assert_eq!(x, y);
    }
}
