//! Synthetic emotion-recognition corpus (stand-in for DAIR.AI / CARER,
//! Saravia et al. 2018 — 6 classes; the paper evaluates on its 2000-sample
//! test split).

use crate::util::rng::Rng;

use super::synth_text::{generate, CorpusSpec, TextDataset};

pub const NUM_CLASSES: usize = 6;
pub const TRAIN_SIZE: usize = 16_000;
pub const TEST_SIZE: usize = 2_000;

const CLASS_NAMES: [&str; 6] = ["sadness", "joy", "love", "anger", "fear", "surprise"];

const SADNESS: &[&str] = &[
    "sad", "lonely", "depressed", "miserable", "crying", "tears", "grief", "hopeless",
    "heartbroken", "gloomy", "sorrow", "hurt", "empty", "lost", "awful", "down", "blue",
    "devastated", "disappointed", "regret", "mourning", "despair", "unhappy", "broken",
];
const JOY: &[&str] = &[
    "happy", "joyful", "excited", "wonderful", "amazing", "great", "delighted", "smile",
    "laughing", "cheerful", "fantastic", "thrilled", "fun", "glad", "awesome", "bright",
    "celebrate", "enjoying", "pleased", "sunshine", "blessed", "content", "ecstatic", "yay",
];
const LOVE: &[&str] = &[
    "love", "loving", "adore", "sweet", "caring", "darling", "affection", "romantic",
    "cherish", "devoted", "tender", "warmth", "heart", "beloved", "fond", "passion",
    "hug", "kiss", "soulmate", "dear", "gentle", "admire", "treasure", "valentine",
];
const ANGER: &[&str] = &[
    "angry", "furious", "mad", "rage", "annoyed", "irritated", "hate", "outraged",
    "frustrated", "livid", "disgusted", "hostile", "bitter", "resentful", "fuming",
    "insulted", "offended", "pissed", "temper", "yelling", "shouting", "grudge", "cross", "irate",
];
const FEAR: &[&str] = &[
    "afraid", "scared", "terrified", "anxious", "nervous", "panic", "frightened", "worried",
    "dread", "horror", "alarmed", "uneasy", "shaking", "trembling", "paranoid", "threatened",
    "insecure", "timid", "phobia", "startled", "creepy", "danger", "helpless", "tense",
];
const SURPRISE: &[&str] = &[
    "surprised", "shocked", "astonished", "amazed", "stunned", "unexpected", "sudden",
    "unbelievable", "incredible", "speechless", "wow", "startling", "curious", "strange",
    "weird", "odd", "bizarre", "remarkable", "extraordinary", "mysterious", "impressed",
    "overwhelmed", "funny", "dazed",
];

fn spec() -> CorpusSpec<'static> {
    const WORDS: [&[&str]; 6] = [SADNESS, JOY, LOVE, ANGER, FEAR, SURPRISE];
    CorpusSpec {
        name: "emotion",
        class_names: &CLASS_NAMES,
        class_words: &WORDS,
        signal: 0.17,
        len_range: (8, 28),
        filler: 1600,
        priors: &[],
        label_noise: 0.06,
    }
}

/// (train, test) splits; deterministic in `seed`. Test uses an independent
/// RNG stream so changing TRAIN_SIZE never changes the test set.
pub fn load(seed: u64) -> (TextDataset, TextDataset) {
    let mut root = Rng::new(seed);
    let mut train_rng = root.fork(1);
    let mut test_rng = root.fork(2);
    let s = spec();
    let mut train = generate(&s, TRAIN_SIZE, &mut train_rng);
    train.name = "emotion-train".into();
    let mut test = generate(&s, TEST_SIZE, &mut test_rng);
    test.name = "emotion-test".into();
    (train, test)
}

/// Smaller split for unit/integration tests.
pub fn load_small(seed: u64, train_n: usize, test_n: usize) -> (TextDataset, TextDataset) {
    let mut root = Rng::new(seed);
    let mut train_rng = root.fork(1);
    let mut test_rng = root.fork(2);
    let s = spec();
    (generate(&s, train_n, &mut train_rng), generate(&s, test_n, &mut test_rng))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_paper_protocol() {
        let (train, test) = load(0);
        assert_eq!(train.len(), TRAIN_SIZE);
        assert_eq!(test.len(), TEST_SIZE);
        assert_eq!(train.num_classes, 6);
    }

    #[test]
    fn roughly_balanced() {
        let (_, test) = load(0);
        for c in test.class_histogram() {
            assert!(c > 230 && c < 440, "histogram skewed: {c}");
        }
    }

    #[test]
    fn train_and_test_disjoint_streams() {
        let (train, test) = load(0);
        assert_ne!(train.texts[0], test.texts[0]);
        // changing nothing reproduces identical data
        let (train2, test2) = load(0);
        assert_eq!(train.texts, train2.texts);
        assert_eq!(test.texts, test2.texts);
    }

    #[test]
    fn different_seeds_differ() {
        let (a, _) = load(0);
        let (b, _) = load(1);
        assert_ne!(a.texts, b.texts);
    }
}
