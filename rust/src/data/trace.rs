//! Serving workload traces: arrival-time generators for the serving bench
//! (S1). Real request logs are not available offline, so we synthesize the
//! standard shapes used in serving papers: Poisson (open-loop), bursty
//! (Markov-modulated) and diurnal-scaled.

use crate::util::rng::Rng;
use std::time::Duration;

/// One request arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// offset from trace start
    pub at: Duration,
    /// index into the request text pool
    pub text_id: usize,
}

/// Arrival process shapes.
#[derive(Debug, Clone, Copy)]
pub enum TraceKind {
    /// Poisson with constant rate (requests/second).
    Poisson { rate: f64 },
    /// Two-state Markov-modulated Poisson: alternates calm/burst.
    Bursty { calm_rate: f64, burst_rate: f64, mean_phase_s: f64 },
    /// Sinusoidal rate between lo and hi over `period_s` (diurnal pattern,
    /// compressed).
    Diurnal { lo_rate: f64, hi_rate: f64, period_s: f64 },
}

/// Generate `n` arrivals.
pub fn generate(kind: TraceKind, n: usize, pool_size: usize, rng: &mut Rng) -> Vec<Arrival> {
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut burst = false;
    let mut phase_left = 0.0f64;
    for _ in 0..n {
        let rate = match kind {
            TraceKind::Poisson { rate } => rate,
            TraceKind::Bursty { calm_rate, burst_rate, mean_phase_s } => {
                if phase_left <= 0.0 {
                    burst = !burst;
                    phase_left = exp_sample(rng, 1.0 / mean_phase_s.max(1e-9));
                }
                if burst {
                    burst_rate
                } else {
                    calm_rate
                }
            }
            TraceKind::Diurnal { lo_rate, hi_rate, period_s } => {
                let phase = (t / period_s) * std::f64::consts::TAU;
                lo_rate + (hi_rate - lo_rate) * 0.5 * (1.0 - phase.cos())
            }
        };
        let gap = exp_sample(rng, rate.max(1e-9));
        t += gap;
        phase_left -= gap;
        out.push(Arrival {
            at: Duration::from_secs_f64(t),
            text_id: rng.below(pool_size.max(1)),
        });
    }
    out
}

/// Exponential inter-arrival sample with the given rate.
fn exp_sample(rng: &mut Rng, rate: f64) -> f64 {
    let u = loop {
        let u = rng.f64();
        if u > 0.0 {
            break u;
        }
    };
    -u.ln() / rate
}

/// Trace statistics for reporting.
pub fn summarize(arrivals: &[Arrival]) -> (f64, f64) {
    if arrivals.len() < 2 {
        return (0.0, 0.0);
    }
    let total = arrivals.last().unwrap().at.as_secs_f64();
    let mean_rate = arrivals.len() as f64 / total.max(1e-9);
    // peak rate over 100ms windows
    let mut peak = 0usize;
    let mut lo = 0usize;
    for hi in 0..arrivals.len() {
        while arrivals[hi].at - arrivals[lo].at > Duration::from_millis(100) {
            lo += 1;
        }
        peak = peak.max(hi - lo + 1);
    }
    (mean_rate, peak as f64 * 10.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_respected() {
        let mut rng = Rng::new(0);
        let tr = generate(TraceKind::Poisson { rate: 100.0 }, 5000, 64, &mut rng);
        assert_eq!(tr.len(), 5000);
        let (mean, _) = summarize(&tr);
        assert!((mean - 100.0).abs() < 10.0, "mean rate {mean}");
        // arrivals strictly increasing
        assert!(tr.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn bursty_has_higher_peak_than_poisson() {
        let mut rng = Rng::new(1);
        let p = generate(TraceKind::Poisson { rate: 50.0 }, 4000, 8, &mut rng);
        let b = generate(
            TraceKind::Bursty { calm_rate: 10.0, burst_rate: 500.0, mean_phase_s: 0.5 },
            4000,
            8,
            &mut rng,
        );
        let (_, peak_p) = summarize(&p);
        let (_, peak_b) = summarize(&b);
        assert!(peak_b > peak_p * 2.0, "poisson peak {peak_p}, bursty peak {peak_b}");
    }

    #[test]
    fn diurnal_rate_oscillates() {
        let mut rng = Rng::new(2);
        let tr = generate(
            TraceKind::Diurnal { lo_rate: 20.0, hi_rate: 200.0, period_s: 2.0 },
            4000,
            8,
            &mut rng,
        );
        // rate peaks at the middle of each period (phase π) and bottoms at
        // the period boundary: compare the two quarter-period windows
        let peak = tr
            .iter()
            .filter(|a| {
                let p = a.at.as_secs_f64() % 2.0;
                (0.75..1.25).contains(&p)
            })
            .count();
        let trough = tr
            .iter()
            .filter(|a| {
                let p = a.at.as_secs_f64() % 2.0;
                !(0.25..1.75).contains(&p)
            })
            .count();
        assert!(peak > trough * 2, "peak {peak} vs trough {trough}");
    }

    #[test]
    fn deterministic() {
        let a = generate(TraceKind::Poisson { rate: 10.0 }, 100, 4, &mut Rng::new(7));
        let b = generate(TraceKind::Poisson { rate: 10.0 }, 100, 4, &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
