//! Shared machinery for synthetic text-classification corpora.
//!
//! Sentences are sampled from a class-conditional mixture: with probability
//! `signal` a word is drawn from the class lexicon, otherwise from shared
//! function/filler vocabulary. This mirrors what a BERT-Tiny classifier
//! actually exploits in the real CARER / SMS-spam data — class-indicative
//! lexical features on a common background — while remaining fully
//! deterministic from a seed.

use crate::util::rng::Rng;

/// A labelled text-classification dataset.
#[derive(Debug, Clone)]
pub struct TextDataset {
    pub name: String,
    pub texts: Vec<String>,
    pub labels: Vec<i32>,
    pub num_classes: usize,
    pub class_names: Vec<String>,
}

impl TextDataset {
    pub fn len(&self) -> usize {
        self.texts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.texts.is_empty()
    }

    /// Per-class sample counts.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

/// Common English function words (shared, class-neutral background).
pub const FUNCTION_WORDS: &[&str] = &[
    "i", "you", "the", "a", "an", "it", "is", "was", "am", "are", "to", "of", "and", "in",
    "that", "my", "me", "so", "for", "on", "with", "this", "but", "be", "have", "had", "not",
    "at", "as", "we", "they", "he", "she", "all", "just", "like", "really", "very", "when",
    "what", "how", "there", "about", "out", "up", "her", "him", "them", "one", "because",
];

/// Deterministic filler vocabulary (generic nouns/verbs, `filler0…fillerN`
/// style pseudo-words mixed with a neutral core so the hash-token embedding
/// table gets realistic occupancy).
pub fn filler_vocab(n: usize) -> Vec<String> {
    const CORE: &[&str] = &[
        "day", "time", "work", "home", "going", "today", "people", "things", "night",
        "week", "friend", "made", "back", "still", "then", "know", "think", "feel",
        "being", "life", "even", "some", "other", "after", "before", "again", "never",
        "always", "around", "little", "while", "right", "left", "thing", "went", "got",
    ];
    let mut v: Vec<String> = CORE.iter().map(|s| s.to_string()).collect();
    let syll = ["ka", "lo", "mi", "ter", "van", "su", "ren", "ba", "chi", "dor", "el", "fu"];
    let mut i = 0usize;
    while v.len() < n {
        let a = syll[i % syll.len()];
        let b = syll[(i / syll.len()) % syll.len()];
        let c = syll[(i * 7 + 3) % syll.len()];
        // the numeric suffix guarantees uniqueness across the whole list
        v.push(format!("{a}{b}{c}{i}"));
        i += 1;
    }
    v.truncate(n);
    v
}

/// Parameters of a synthetic corpus.
pub struct CorpusSpec<'a> {
    pub name: &'a str,
    pub class_names: &'a [&'a str],
    /// Per-class signal lexicons.
    pub class_words: &'a [&'a [&'a str]],
    /// P(word is drawn from the class lexicon).
    pub signal: f64,
    /// Sentence length range (words), inclusive.
    pub len_range: (usize, usize),
    /// Filler vocabulary size.
    pub filler: usize,
    /// Optional per-class priors (uniform when empty).
    pub priors: &'a [f64],
    /// Label noise: probability a sample's *label* is resampled uniformly
    /// (its text keeps the true class signal). Bounds achievable accuracy
    /// below 100%, matching the regime of the paper's real datasets.
    pub label_noise: f64,
}

/// Sample one sentence for `class`.
pub fn sample_sentence(spec: &CorpusSpec, class: usize, rng: &mut Rng, filler: &[String]) -> String {
    let n = rng.range(spec.len_range.0, spec.len_range.1 + 1);
    let words = spec.class_words[class];
    let mut out: Vec<&str> = Vec::with_capacity(n);
    for _ in 0..n {
        let r = rng.f64();
        if r < spec.signal {
            out.push(words[rng.below(words.len())]);
        } else if r < spec.signal + 0.25 {
            out.push(FUNCTION_WORDS[rng.below(FUNCTION_WORDS.len())]);
        } else {
            out.push(&filler[rng.below(filler.len())]);
        }
    }
    out.join(" ")
}

/// Generate a full dataset of `n` samples.
pub fn generate(spec: &CorpusSpec, n: usize, rng: &mut Rng) -> TextDataset {
    let filler = filler_vocab(spec.filler);
    let k = spec.class_names.len();
    assert_eq!(spec.class_words.len(), k);
    let mut texts = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let class = if spec.priors.is_empty() {
            rng.below(k)
        } else {
            rng.weighted(spec.priors)
        };
        texts.push(sample_sentence(spec, class, rng, &filler));
        let label = if spec.label_noise > 0.0 && rng.chance(spec.label_noise) {
            rng.below(k)
        } else {
            class
        };
        labels.push(label as i32);
    }
    TextDataset {
        name: spec.name.to_string(),
        texts,
        labels,
        num_classes: k,
        class_names: spec.class_names.iter().map(|s| s.to_string()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> CorpusSpec<'static> {
        CorpusSpec {
            name: "tiny",
            class_names: &["a", "b"],
            class_words: &[&["alpha", "apex"], &["beta", "blaze"]],
            signal: 0.5,
            len_range: (4, 8),
            filler: 50,
            priors: &[],
            label_noise: 0.0,
        }
    }

    #[test]
    fn generate_is_deterministic() {
        let spec = tiny_spec();
        let a = generate(&spec, 100, &mut Rng::new(7));
        let b = generate(&spec, 100, &mut Rng::new(7));
        assert_eq!(a.texts, b.texts);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn class_words_appear_in_their_class() {
        let spec = tiny_spec();
        let d = generate(&spec, 400, &mut Rng::new(1));
        let mut hits = [0usize; 2];
        for (t, &l) in d.texts.iter().zip(&d.labels) {
            if l == 0 && (t.contains("alpha") || t.contains("apex")) {
                hits[0] += 1;
            }
            if l == 1 && (t.contains("beta") || t.contains("blaze")) {
                hits[1] += 1;
            }
            // cross-contamination impossible by construction
            if l == 0 {
                assert!(!t.contains("beta") && !t.contains("blaze"));
            }
        }
        assert!(hits[0] > 50 && hits[1] > 50, "{hits:?}");
    }

    #[test]
    fn priors_respected() {
        let spec = CorpusSpec { priors: &[0.9, 0.1], ..tiny_spec() };
        let d = generate(&spec, 2000, &mut Rng::new(2));
        let h = d.class_histogram();
        assert!(h[0] > 1650 && h[0] < 1950, "{h:?}");
    }

    #[test]
    fn sentence_lengths_in_range() {
        let spec = tiny_spec();
        let d = generate(&spec, 200, &mut Rng::new(3));
        for t in &d.texts {
            let n = t.split_whitespace().count();
            assert!((4..=8).contains(&n), "{n}");
        }
    }

    #[test]
    fn filler_vocab_distinct() {
        let v = filler_vocab(2000);
        let set: std::collections::HashSet<&String> = v.iter().collect();
        assert_eq!(set.len(), v.len());
    }
}
