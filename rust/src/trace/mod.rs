//! §Observability: process-wide tracing and telemetry core.
//!
//! Design (see ROADMAP "Observability"):
//!
//! * **Per-thread recorders.** Every thread that emits an event lazily
//!   registers one bounded, lock-free [`ring::Ring`] (drop-oldest on
//!   overflow, dropped events counted). Writers never block and never
//!   allocate per event; a global registry only serializes registration
//!   and draining.
//! * **Disabled cost.** Every emission entry point loads one relaxed
//!   [`AtomicBool`] and returns. The disabled path never touches the
//!   thread-local recorder, so threads that only ever run with tracing off
//!   register nothing and allocate nothing.
//! * **Event taxonomy.** [`EventKind`] × [`Category`]: RAII spans
//!   (`Enter`/`Exit`) for stage timing, `Instant` markers for point events
//!   (shed, fault, eviction, plane decode/reuse), `Complete` for
//!   retroactively-timed request-lifecycle slices, and `Counter` for
//!   monotonic tallies.
//! * **Consumers.** [`snapshot`] drains all rings into a [`Snapshot`];
//!   [`chrome`] renders it as Chrome trace-event JSON (Perfetto-loadable,
//!   deterministic field order) and [`prom`] renders current metrics as a
//!   Prometheus-style text exposition.
//!
//! Timestamps are nanoseconds since a process-local epoch fixed the first
//! time it is needed ([`now_ns`]); they are comparable within a process
//! only.

pub mod chrome;
pub mod prom;
pub mod ring;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::sync::lock_recover;
use ring::Ring;

/// Master switch: one relaxed load on every emission entry point.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Capacity (events) used for rings created after the last
/// [`set_ring_capacity`] call.
static RING_CAPACITY: AtomicUsize = AtomicUsize::new(ring::DEFAULT_CAPACITY);

/// Cumulative count of events lost to ring overflow across all drains.
static DROPPED_TOTAL: AtomicU64 = AtomicU64::new(0);

/// All registered per-thread rings, in registration order (= exporter tid).
static REGISTRY: Mutex<Vec<(String, Arc<Ring>)>> = Mutex::new(Vec::new());

/// Monotonic named counters (see [`count`]).
static COUNTERS: Mutex<BTreeMap<&'static str, u64>> = Mutex::new(BTreeMap::new());

/// Leaked copies of dynamic event names (see [`intern`]).
static INTERNED: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());

/// Process-local time origin for every `ts_ns` in this module.
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    /// This thread's ring, created on first *enabled* emission.
    static LOCAL: std::cell::OnceCell<Arc<Ring>> = const { std::cell::OnceCell::new() };
}

/// What a recorded [`Event`] describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Span opened (`ts_ns` = entry time).
    Enter,
    /// Span closed (`ts_ns` = exit time; matches the nearest open `Enter`
    /// on the same thread).
    Exit,
    /// Point-in-time marker.
    Instant,
    /// Counter sample (`a` = value).
    Counter,
    /// Retroactively-timed slice: `ts_ns` = start, `a` = duration in ns,
    /// `b` = lane (used for per-request lifecycle rows).
    Complete,
}

/// Coarse event taxonomy used for exporter grouping and lint scoping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    /// Request lifecycle (ingress, queue, shed, per-request slices).
    Request,
    /// Batch formation and execution stages inside the coordinator.
    Batch,
    /// Shard residency traffic: faults, prefetches, evictions, plane cache.
    Shard,
    /// Pooled kernel dispatch (chunk granularity only — never inner loops).
    Kernel,
    /// Autotune pipeline stages.
    Autotune,
}

impl Category {
    /// Stable lowercase label used by the exporters.
    pub fn as_str(self) -> &'static str {
        match self {
            Category::Request => "request",
            Category::Batch => "batch",
            Category::Shard => "shard",
            Category::Kernel => "kernel",
            Category::Autotune => "autotune",
        }
    }
}

/// One recorded telemetry event. `a`/`b` are kind-specific payloads
/// (byte counts, batch sizes, durations, lanes — see each emitter).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// What happened.
    pub kind: EventKind,
    /// Subsystem grouping.
    pub cat: Category,
    /// Static (or [`intern`]ed) event name.
    pub name: &'static str,
    /// Nanoseconds since the process-local epoch.
    pub ts_ns: u64,
    /// First payload word (meaning depends on `kind`/emitter).
    pub a: u64,
    /// Second payload word.
    pub b: u64,
}

/// Everything drained from every registered thread by [`snapshot`].
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    /// `(thread name, events oldest-first)`, in registration order; the
    /// index is the exporter thread id.
    pub threads: Vec<(String, Vec<Event>)>,
    /// Events lost to ring overflow in *this* drain.
    pub dropped: u64,
}

impl Snapshot {
    /// Total number of events across all threads.
    pub fn total_events(&self) -> usize {
        self.threads.iter().map(|(_, evs)| evs.len()).sum()
    }
}

/// Is tracing currently enabled? One relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on or off process-wide. Spans already open keep their
/// balance: a span armed while enabled records its exit even if tracing is
/// disabled before it drops.
pub fn set_enabled(on: bool) {
    if on {
        let _ = epoch(); // fix the time origin before the first event
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Set the capacity (in events, rounded up to a power of two) for rings
/// created *after* this call; existing per-thread rings are unaffected.
pub fn set_ring_capacity(events: usize) {
    RING_CAPACITY.store(events.max(2), Ordering::Relaxed);
}

/// Capacity (in events) that rings created *now* would receive. Exposed so
/// the Prometheus exposition can pair [`dropped_total`] with the ring size
/// the drops were measured against.
pub fn ring_capacity() -> usize {
    RING_CAPACITY.load(Ordering::Relaxed)
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process-local trace epoch.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Convert an [`Instant`] to nanoseconds since the trace epoch (saturating
/// to 0 for instants captured before the epoch was fixed).
pub fn epoch_ns(i: Instant) -> u64 {
    i.saturating_duration_since(epoch()).as_nanos() as u64
}

/// Push to this thread's ring, registering it on first use.
fn record(ev: Event) {
    LOCAL.with(|cell| {
        let ring = cell.get_or_init(|| {
            let ring = Arc::new(Ring::with_capacity(RING_CAPACITY.load(Ordering::Relaxed)));
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| "thread".to_string());
            lock_recover(&REGISTRY).push((name, Arc::clone(&ring)));
            ring
        });
        ring.push(ev);
    });
}

/// RAII span guard: records `Enter` on creation (when tracing is enabled)
/// and the matching `Exit` on drop. Cheap to create when disabled — a
/// relaxed load, no allocation, no thread-local touch.
#[must_use = "a span measures the scope it is bound to; binding it to `_` drops it immediately"]
pub struct Span {
    armed: bool,
    cat: Category,
    name: &'static str,
}

impl Span {
    fn open(cat: Category, name: &'static str, a: u64, b: u64) -> Span {
        let armed = enabled();
        if armed {
            record(Event { kind: EventKind::Enter, cat, name, ts_ns: now_ns(), a, b });
        }
        Span { armed, cat, name }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.armed {
            record(Event {
                kind: EventKind::Exit,
                cat: self.cat,
                name: self.name,
                ts_ns: now_ns(),
                a: 0,
                b: 0,
            });
        }
    }
}

/// Open a span with no payload.
pub fn span(cat: Category, name: &'static str) -> Span {
    Span::open(cat, name, 0, 0)
}

/// Open a span carrying two payload words (recorded on the `Enter` event).
pub fn span_args(cat: Category, name: &'static str, a: u64, b: u64) -> Span {
    Span::open(cat, name, a, b)
}

/// Chunk-granularity kernel span (sugar for [`Category::Kernel`]): `a` is
/// the chunk's first row, `b` its row count. The `no-timing-in-kernels`
/// lint rule allows exactly this, at dispatch-chunk scope only.
pub fn kernel_span(name: &'static str, a: u64, b: u64) -> Span {
    Span::open(Category::Kernel, name, a, b)
}

/// Record a point-in-time marker with two payload words.
pub fn instant(cat: Category, name: &'static str, a: u64, b: u64) {
    if enabled() {
        record(Event { kind: EventKind::Instant, cat, name, ts_ns: now_ns(), a, b });
    }
}

/// Record a retroactively-timed slice (used for per-request lifecycle
/// breakdowns where start/end are captured as [`Instant`]s first).
pub fn complete(cat: Category, name: &'static str, start_ns: u64, dur_ns: u64, lane: u64) {
    if enabled() {
        record(Event { kind: EventKind::Complete, cat, name, ts_ns: start_ns, a: dur_ns, b: lane });
    }
}

/// Add `delta` to the named monotonic counter (no-op while disabled).
pub fn count(name: &'static str, delta: u64) {
    if enabled() {
        *lock_recover(&COUNTERS).entry(name).or_insert(0) += delta;
    }
}

/// Snapshot of all monotonic counters (sorted by name).
pub fn counters() -> BTreeMap<&'static str, u64> {
    lock_recover(&COUNTERS).clone()
}

/// Clear all monotonic counters (test isolation helper).
pub fn reset_counters() {
    lock_recover(&COUNTERS).clear();
}

/// Intern a dynamic string (e.g. a shard name) as a `&'static str` event
/// name. Leaks one copy per distinct string for the process lifetime; call
/// only on enabled paths and only for small, bounded name sets.
pub fn intern(s: &str) -> &'static str {
    let mut g = lock_recover(&INTERNED);
    if let Some(&e) = g.iter().find(|e| **e == s) {
        return e;
    }
    let leaked: &'static str = Box::leak(s.to_string().into_boxed_str());
    g.push(leaked);
    leaked
}

/// Cumulative events lost to ring overflow across all drains so far.
pub fn dropped_total() -> u64 {
    DROPPED_TOTAL.load(Ordering::Relaxed)
}

/// Drain every registered thread ring into a [`Snapshot`]. Draining
/// consumes: events appear in exactly one snapshot. Threads keep recording
/// concurrently; anything pushed during the drain shows up next time.
pub fn snapshot() -> Snapshot {
    let reg = lock_recover(&REGISTRY);
    let mut snap = Snapshot::default();
    for (name, ring) in reg.iter() {
        let mut evs = Vec::new();
        snap.dropped += ring.drain(&mut evs);
        snap.threads.push((name.clone(), evs));
    }
    DROPPED_TOTAL.fetch_add(snap.dropped, Ordering::Relaxed);
    snap
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn category_labels_are_stable() {
        assert_eq!(Category::Request.as_str(), "request");
        assert_eq!(Category::Kernel.as_str(), "kernel");
        assert_eq!(Category::Autotune.as_str(), "autotune");
    }

    #[test]
    fn intern_dedupes_and_returns_stable_refs() {
        let a = intern("shard-intern-test");
        let b = intern("shard-intern-test");
        assert!(std::ptr::eq(a, b), "same string must intern to the same allocation");
        assert_eq!(a, "shard-intern-test");
    }

    #[test]
    fn disabled_span_is_unarmed() {
        // the process-wide flag is off by default in this test binary; a
        // span created while disabled must not arm (and so records nothing
        // on drop even if another test enables tracing concurrently — unit
        // tests here never enable it)
        if !enabled() {
            let sp = span(Category::Batch, "noop");
            assert!(!sp.armed);
        }
    }

    #[test]
    fn epoch_ns_saturates_before_epoch() {
        let before = Instant::now();
        let _ = epoch();
        assert_eq!(epoch_ns(before), 0);
        let after = Instant::now();
        // non-decreasing from the epoch on
        assert!(epoch_ns(after) <= now_ns());
    }
}
