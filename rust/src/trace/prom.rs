//! Prometheus-style text exposition of the serving metrics + trace
//! counters.
//!
//! This is a point-in-time snapshot renderer, not an HTTP endpoint: the
//! coordinator exposes it as `Server::telemetry_text()` and the
//! `splitquant trace` CLI subcommand prints it after a run. The output
//! follows the Prometheus text format (`# HELP` / `# TYPE` headers, one
//! `name{labels} value` sample per line) and is deterministic: metric
//! families are emitted in a fixed order and every labelled family
//! iterates a `BTreeMap` (the `deterministic-iteration` contract).

use std::fmt::Write as _;

use crate::coordinator::Metrics;
use crate::util::stats::LogHistogram;

fn family(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn sample(out: &mut String, name: &str, labels: &str, value: u64) {
    let _ = writeln!(out, "{name}{labels} {value}");
}

fn quantiles(out: &mut String, stage: &str, h: &LogHistogram) {
    for (q, label) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99"), (0.999, "0.999")] {
        let labels = format!("{{stage=\"{stage}\",quantile=\"{label}\"}}");
        sample(out, "splitquant_request_stage_us", &labels, h.quantile_us(q));
    }
    let labels = format!("{{stage=\"{stage}\"}}");
    sample(out, "splitquant_request_stage_count", &labels, h.len() as u64);
}

/// Render `m` (plus the global trace counters) in the Prometheus text
/// exposition format. Field order is fixed; repeated calls over unchanged
/// metrics yield identical output.
pub fn exposition(m: &Metrics) -> String {
    let mut out = String::new();
    let simple: [(&str, &str, u64); 14] = [
        ("splitquant_requests_completed_total", "requests served", m.completed as u64),
        ("splitquant_requests_shed_total", "requests shed (queue full)", m.shed as u64),
        (
            "splitquant_requests_shed_expired_total",
            "queued requests shed on expiry",
            m.shed_expired as u64,
        ),
        ("splitquant_exec_time_us_total", "executor time, us", m.exec_time.as_micros() as u64),
        (
            "splitquant_exec_panics_total",
            "executor panics contained at the batch boundary",
            m.exec_panics as u64,
        ),
        ("splitquant_batcher_polls_total", "idle batcher wake-ups", m.batcher_polls as u64),
        ("splitquant_shard_faults_total", "shard demand misses", m.shard_faults as u64),
        ("splitquant_shard_evictions_total", "shards evicted", m.shard_evictions as u64),
        (
            "splitquant_shard_integrity_failures_total",
            "shard reads failing CRC/parse verification",
            m.integrity_failures as u64,
        ),
        (
            "splitquant_shard_io_retries_total",
            "shard read attempts beyond the first",
            m.io_retries as u64,
        ),
        (
            "splitquant_shards_quarantined_total",
            "shards quarantined after retry exhaustion",
            m.shards_quarantined as u64,
        ),
        ("splitquant_bytes_paged_in_total", "bytes paged in", m.bytes_paged_in as u64),
        ("splitquant_plane_decodes_total", "low-bit plane decodes", m.plane_decodes as u64),
        ("splitquant_plane_reuses_total", "plane-cache reuses", m.plane_reuses as u64),
    ];
    for (name, help, v) in simple {
        family(&mut out, name, "counter", help);
        sample(&mut out, name, "", v);
    }
    // health / readiness gauges: `up` says the process is alive to answer at
    // all; `degraded` says it is shedding load or quarantining shards — a
    // scrape-friendly readiness signal that never requires a second endpoint
    family(&mut out, "splitquant_up", "gauge", "process serving at all (always 1 when scraped)");
    sample(&mut out, "splitquant_up", "", 1);
    family(
        &mut out,
        "splitquant_degraded",
        "gauge",
        "1 when panics were contained or shards are quarantined",
    );
    sample(
        &mut out,
        "splitquant_degraded",
        "",
        u64::from(m.exec_panics + m.shards_quarantined > 0),
    );
    family(&mut out, "splitquant_batches_total", "counter", "batches per compiled size");
    for (size, n) in &m.batches_by_size {
        sample(&mut out, "splitquant_batches_total", &format!("{{size=\"{size}\"}}"), *n as u64);
    }
    family(&mut out, "splitquant_slots_total", "counter", "request slots (real vs padded)");
    sample(&mut out, "splitquant_slots_total", "{kind=\"real\"}", m.real_slots as u64);
    sample(&mut out, "splitquant_slots_total", "{kind=\"padded\"}", m.padded_slots as u64);
    family(&mut out, "splitquant_request_stage_us", "gauge", "stage latency quantiles, us");
    quantiles(&mut out, "total", &m.latency);
    quantiles(&mut out, "queue", &m.queue_us);
    quantiles(&mut out, "batch", &m.batch_us);
    quantiles(&mut out, "exec", &m.exec_us);
    quantiles(&mut out, "fault", &m.fault_us);
    family(&mut out, "splitquant_trace_counter", "counter", "monotonic trace counters");
    for (name, v) in super::counters() {
        sample(&mut out, "splitquant_trace_counter", &format!("{{name=\"{name}\"}}"), v);
    }
    family(&mut out, "splitquant_trace_dropped_events_total", "counter", "ring overflow drops");
    sample(&mut out, "splitquant_trace_dropped_events_total", "", super::dropped_total());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_is_deterministic_and_well_formed() {
        let mut m = Metrics::default();
        m.record_batch(5, 8, std::time::Duration::from_millis(3));
        for _ in 0..5 {
            m.record_done(std::time::Duration::from_millis(4));
        }
        let a = exposition(&m);
        let b = exposition(&m);
        assert_eq!(a, b, "fixed field order");
        assert!(a.contains("splitquant_requests_completed_total 5"), "{a}");
        assert!(a.contains("splitquant_batches_total{size=\"8\"} 1"), "{a}");
        assert!(a.contains("splitquant_request_stage_us{stage=\"total\",quantile=\"0.5\"}"), "{a}");
        assert!(a.contains("splitquant_up 1"), "{a}");
        assert!(a.contains("splitquant_degraded 0"), "{a}");
        for line in a.lines() {
            assert!(
                line.starts_with('#') || line.starts_with("splitquant_"),
                "stray line: {line}"
            );
        }
    }

    #[test]
    fn degraded_gauge_tracks_panics_and_quarantines() {
        let mut m = Metrics::default();
        m.exec_panics = 1;
        let a = exposition(&m);
        assert!(a.contains("splitquant_degraded 1"), "{a}");
        assert!(a.contains("splitquant_exec_panics_total 1"), "{a}");
        let mut m = Metrics::default();
        m.shards_quarantined = 3;
        m.io_retries = 7;
        m.integrity_failures = 4;
        m.shed_expired = 2;
        let b = exposition(&m);
        assert!(b.contains("splitquant_degraded 1"), "{b}");
        assert!(b.contains("splitquant_shards_quarantined_total 3"), "{b}");
        assert!(b.contains("splitquant_shard_io_retries_total 7"), "{b}");
        assert!(b.contains("splitquant_shard_integrity_failures_total 4"), "{b}");
        assert!(b.contains("splitquant_requests_shed_expired_total 2"), "{b}");
    }
}
