//! Prometheus-style text exposition of the serving metrics + trace
//! counters.
//!
//! This is a point-in-time snapshot renderer, not an HTTP endpoint: the
//! coordinator exposes it as `Server::telemetry_text()` and the
//! `splitquant trace` CLI subcommand prints it after a run. The output
//! follows the Prometheus text format (`# HELP` / `# TYPE` headers, one
//! `name{labels} value` sample per line) and is deterministic: metric
//! families are emitted in a fixed order and every labelled family
//! iterates a `BTreeMap` (the `deterministic-iteration` contract).

use std::fmt::Write as _;

use crate::coordinator::Metrics;
use crate::util::stats::LogHistogram;

fn family(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn sample(out: &mut String, name: &str, labels: &str, value: u64) {
    let _ = writeln!(out, "{name}{labels} {value}");
}

fn quantiles(out: &mut String, stage: &str, h: &LogHistogram) {
    for (q, label) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99"), (0.999, "0.999")] {
        let labels = format!("{{stage=\"{stage}\",quantile=\"{label}\"}}");
        sample(out, "splitquant_request_stage_us", &labels, h.quantile_us(q));
    }
    let labels = format!("{{stage=\"{stage}\"}}");
    sample(out, "splitquant_request_stage_count", &labels, h.len() as u64);
}

/// Render `m` (plus the global trace counters) in the Prometheus text
/// exposition format. Field order is fixed; repeated calls over unchanged
/// metrics yield identical output.
pub fn exposition(m: &Metrics) -> String {
    let mut out = String::new();
    let simple: [(&str, &str, u64); 14] = [
        ("splitquant_requests_completed_total", "requests served", m.completed as u64),
        ("splitquant_requests_shed_total", "requests shed (queue full)", m.shed as u64),
        (
            "splitquant_requests_shed_expired_total",
            "queued requests shed on expiry",
            m.shed_expired as u64,
        ),
        ("splitquant_exec_time_us_total", "executor time, us", m.exec_time.as_micros() as u64),
        (
            "splitquant_exec_panics_total",
            "executor panics contained at the batch boundary",
            m.exec_panics as u64,
        ),
        ("splitquant_batcher_polls_total", "idle batcher wake-ups", m.batcher_polls as u64),
        ("splitquant_shard_faults_total", "shard demand misses", m.shard_faults as u64),
        ("splitquant_shard_evictions_total", "shards evicted", m.shard_evictions as u64),
        (
            "splitquant_shard_integrity_failures_total",
            "shard reads failing CRC/parse verification",
            m.integrity_failures as u64,
        ),
        (
            "splitquant_shard_io_retries_total",
            "shard read attempts beyond the first",
            m.io_retries as u64,
        ),
        (
            "splitquant_shards_quarantined_total",
            "shards quarantined after retry exhaustion",
            m.shards_quarantined as u64,
        ),
        ("splitquant_bytes_paged_in_total", "bytes paged in", m.bytes_paged_in as u64),
        ("splitquant_plane_decodes_total", "low-bit plane decodes", m.plane_decodes as u64),
        ("splitquant_plane_reuses_total", "plane-cache reuses", m.plane_reuses as u64),
    ];
    for (name, help, v) in simple {
        family(&mut out, name, "counter", help);
        sample(&mut out, name, "", v);
    }
    // health / readiness gauges: `up` says the process is alive to answer at
    // all; `degraded` says it is shedding load or quarantining shards — a
    // scrape-friendly readiness signal that never requires a second endpoint
    family(&mut out, "splitquant_up", "gauge", "process serving at all (always 1 when scraped)");
    sample(&mut out, "splitquant_up", "", 1);
    family(
        &mut out,
        "splitquant_degraded",
        "gauge",
        "1 when panics were contained, shards are quarantined, or quantization drift alarmed",
    );
    let drift_alarm = m.qhealth.as_ref().is_some_and(|q| q.drift_alarmed());
    sample(
        &mut out,
        "splitquant_degraded",
        "",
        u64::from(m.exec_panics + m.shards_quarantined > 0 || drift_alarm),
    );
    family(&mut out, "splitquant_batches_total", "counter", "batches per compiled size");
    for (size, n) in &m.batches_by_size {
        sample(&mut out, "splitquant_batches_total", &format!("{{size=\"{size}\"}}"), *n as u64);
    }
    family(&mut out, "splitquant_slots_total", "counter", "request slots (real vs padded)");
    sample(&mut out, "splitquant_slots_total", "{kind=\"real\"}", m.real_slots as u64);
    sample(&mut out, "splitquant_slots_total", "{kind=\"padded\"}", m.padded_slots as u64);
    family(&mut out, "splitquant_request_stage_us", "gauge", "stage latency quantiles, us");
    quantiles(&mut out, "total", &m.latency);
    quantiles(&mut out, "queue", &m.queue_us);
    quantiles(&mut out, "batch", &m.batch_us);
    quantiles(&mut out, "exec", &m.exec_us);
    quantiles(&mut out, "fault", &m.fault_us);
    family(&mut out, "splitquant_trace_counter", "counter", "monotonic trace counters");
    for (name, v) in super::counters() {
        sample(&mut out, "splitquant_trace_counter", &format!("{{name=\"{name}\"}}"), v);
    }
    family(&mut out, "splitquant_trace_dropped_events_total", "counter", "ring overflow drops");
    sample(&mut out, "splitquant_trace_dropped_events_total", "", super::dropped_total());
    family(
        &mut out,
        "splitquant_trace_ring_capacity_events",
        "gauge",
        "per-thread trace ring capacity (events) for rings created now",
    );
    sample(&mut out, "splitquant_trace_ring_capacity_events", "", super::ring_capacity() as u64);
    // Numeric-health families. `splitquant_quant_drift` is emitted even when
    // qhealth never ran (value 0) so alert rules can reference it
    // unconditionally; the per-site/per-layer detail families appear only
    // when a snapshot was folded into the metrics.
    family(
        &mut out,
        "splitquant_quant_drift",
        "gauge",
        "1 when any activation site's EWMA clip fraction alarmed",
    );
    sample(&mut out, "splitquant_quant_drift", "", u64::from(drift_alarm));
    if let Some(q) = &m.qhealth {
        family(
            &mut out,
            "splitquant_qhealth_act_values_total",
            "counter",
            "activation scalars observed per site",
        );
        for s in &q.sites {
            let labels = format!("{{site=\"{}\"}}", s.site);
            sample(&mut out, "splitquant_qhealth_act_values_total", &labels, s.values);
        }
        family(
            &mut out,
            "splitquant_qhealth_act_clipped_total",
            "counter",
            "activation scalars outside the calibrated range per site",
        );
        for s in &q.sites {
            let labels = format!("{{site=\"{}\"}}", s.site);
            sample(&mut out, "splitquant_qhealth_act_clipped_total", &labels, s.clipped);
        }
        family(
            &mut out,
            "splitquant_qhealth_drift_permille",
            "gauge",
            "range overshoot vs calibrated width, per-mille quantiles per site",
        );
        for s in &q.sites {
            for (v, label) in [(s.drift_p50_permille, "0.5"), (s.drift_max_permille, "1")] {
                let labels = format!("{{site=\"{}\",quantile=\"{label}\"}}", s.site);
                sample(&mut out, "splitquant_qhealth_drift_permille", &labels, v);
            }
        }
        family(
            &mut out,
            "splitquant_qhealth_cluster_occupancy_total",
            "counter",
            "weight rows dispatched per split cluster",
        );
        for l in &q.layers {
            for (c, name) in ["lower", "middle", "upper"].iter().enumerate() {
                let labels = format!("{{layer=\"{}\",cluster=\"{name}\"}}", l.layer);
                let v = l.occupancy.get(c).copied().unwrap_or(0);
                sample(&mut out, "splitquant_qhealth_cluster_occupancy_total", &labels, v);
            }
        }
        family(
            &mut out,
            "splitquant_qhealth_dead_clusters",
            "gauge",
            "split clusters with zero occupancy per layer",
        );
        for l in &q.layers {
            let labels = format!("{{layer=\"{}\"}}", l.layer);
            let dead = u64::from(l.dead_clusters);
            sample(&mut out, "splitquant_qhealth_dead_clusters", &labels, dead);
        }
        family(
            &mut out,
            "splitquant_qhealth_ocs_total",
            "counter",
            "outlier-hatch decisions per layer (calls vs batches with hits)",
        );
        for l in &q.layers {
            for (v, kind) in [(l.ocs_calls, "calls"), (l.ocs_hits, "hits")] {
                let labels = format!("{{layer=\"{}\",kind=\"{kind}\"}}", l.layer);
                sample(&mut out, "splitquant_qhealth_ocs_total", &labels, v);
            }
        }
        family(
            &mut out,
            "splitquant_qhealth_outlier_columns_total",
            "counter",
            "activation columns flagged outlier vs columns inspected per layer",
        );
        for l in &q.layers {
            for (v, kind) in [(l.outlier_cols, "outlier"), (l.total_cols, "total")] {
                let labels = format!("{{layer=\"{}\",kind=\"{kind}\"}}", l.layer);
                sample(&mut out, "splitquant_qhealth_outlier_columns_total", &labels, v);
            }
        }
        family(
            &mut out,
            "splitquant_qhealth_shadow_samples_total",
            "counter",
            "requests replayed through the FP32 shadow reference path",
        );
        sample(&mut out, "splitquant_qhealth_shadow_samples_total", "", q.shadow.samples);
        family(
            &mut out,
            "splitquant_qhealth_shadow_top1_agree_total",
            "counter",
            "shadow samples whose served top-1 matched the reference",
        );
        sample(&mut out, "splitquant_qhealth_shadow_top1_agree_total", "", q.shadow.top1_agree);
        family(
            &mut out,
            "splitquant_qhealth_shadow_kl_micro_nats",
            "gauge",
            "served-vs-reference logit KL divergence, micro-nat quantiles",
        );
        let sh = &q.shadow;
        for (v, label) in [(sh.kl_p50_micro_nats, "0.5"), (sh.kl_max_micro_nats, "1")] {
            let labels = format!("{{quantile=\"{label}\"}}");
            sample(&mut out, "splitquant_qhealth_shadow_kl_micro_nats", &labels, v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_is_deterministic_and_well_formed() {
        let mut m = Metrics::default();
        m.record_batch(5, 8, std::time::Duration::from_millis(3));
        for _ in 0..5 {
            m.record_done(std::time::Duration::from_millis(4));
        }
        let a = exposition(&m);
        let b = exposition(&m);
        assert_eq!(a, b, "fixed field order");
        assert!(a.contains("splitquant_requests_completed_total 5"), "{a}");
        assert!(a.contains("splitquant_batches_total{size=\"8\"} 1"), "{a}");
        assert!(a.contains("splitquant_request_stage_us{stage=\"total\",quantile=\"0.5\"}"), "{a}");
        assert!(a.contains("splitquant_up 1"), "{a}");
        assert!(a.contains("splitquant_degraded 0"), "{a}");
        for line in a.lines() {
            assert!(
                line.starts_with('#') || line.starts_with("splitquant_"),
                "stray line: {line}"
            );
        }
    }

    #[test]
    fn degraded_gauge_tracks_panics_and_quarantines() {
        let mut m = Metrics::default();
        m.exec_panics = 1;
        let a = exposition(&m);
        assert!(a.contains("splitquant_degraded 1"), "{a}");
        assert!(a.contains("splitquant_exec_panics_total 1"), "{a}");
        let mut m = Metrics::default();
        m.shards_quarantined = 3;
        m.io_retries = 7;
        m.integrity_failures = 4;
        m.shed_expired = 2;
        let b = exposition(&m);
        assert!(b.contains("splitquant_degraded 1"), "{b}");
        assert!(b.contains("splitquant_shards_quarantined_total 3"), "{b}");
        assert!(b.contains("splitquant_shard_io_retries_total 7"), "{b}");
        assert!(b.contains("splitquant_shard_integrity_failures_total 4"), "{b}");
        assert!(b.contains("splitquant_requests_shed_expired_total 2"), "{b}");
    }

    #[test]
    fn drift_gauge_and_ring_capacity_always_emitted() {
        let m = Metrics::default();
        let a = exposition(&m);
        assert!(a.contains("splitquant_quant_drift 0"), "{a}");
        assert!(a.contains("splitquant_trace_ring_capacity_events"), "{a}");
        assert!(!a.contains("splitquant_qhealth_shadow_samples_total"), "{a}");
        assert!(!a.contains("splitquant_qhealth_act_values_total"), "{a}");
    }

    #[test]
    fn qhealth_families_expose_snapshot_and_flip_degraded() {
        let mut m = Metrics::default();
        m.qhealth = Some(crate::qhealth::QHealthSnapshot {
            sites: vec![crate::qhealth::SiteSnapshot {
                site: 0,
                calibrated: Some((-1.0, 1.0)),
                observed: Some((-1.5, 1.2)),
                values: 100,
                clipped: 7,
                batches: 2,
                ewma_clip: 0.07,
                alarm: true,
                drift_p50_permille: 100,
                drift_max_permille: 350,
            }],
            layers: vec![crate::qhealth::LayerSnapshot {
                layer: "encoder.0.attn.q".into(),
                occupancy: [3, 0, 5],
                dead_clusters: 1,
                dispatches: 2,
                ocs_calls: 2,
                ocs_hits: 1,
                outlier_cols: 4,
                total_cols: 64,
            }],
            shadow: crate::qhealth::ShadowSnapshot {
                samples: 8,
                top1_agree: 7,
                kl_mean_micro_nats: 12.5,
                kl_p50_micro_nats: 9,
                kl_max_micro_nats: 40,
            },
        });
        let b = exposition(&m);
        assert_eq!(b, exposition(&m), "fixed field order");
        assert!(b.contains("splitquant_quant_drift 1"), "{b}");
        assert!(b.contains("splitquant_degraded 1"), "alarm must feed degraded: {b}");
        assert!(b.contains("splitquant_qhealth_act_values_total{site=\"0\"} 100"), "{b}");
        assert!(b.contains("splitquant_qhealth_act_clipped_total{site=\"0\"} 7"), "{b}");
        assert!(
            b.contains("splitquant_qhealth_drift_permille{site=\"0\",quantile=\"1\"} 350"),
            "{b}"
        );
        assert!(
            b.contains(
                "splitquant_qhealth_cluster_occupancy_total\
                 {layer=\"encoder.0.attn.q\",cluster=\"middle\"} 0"
            ),
            "{b}"
        );
        assert!(
            b.contains("splitquant_qhealth_dead_clusters{layer=\"encoder.0.attn.q\"} 1"),
            "{b}"
        );
        assert!(
            b.contains("splitquant_qhealth_ocs_total{layer=\"encoder.0.attn.q\",kind=\"hits\"} 1"),
            "{b}"
        );
        assert!(
            b.contains(
                "splitquant_qhealth_outlier_columns_total\
                 {layer=\"encoder.0.attn.q\",kind=\"outlier\"} 4"
            ),
            "{b}"
        );
        assert!(b.contains("splitquant_qhealth_shadow_samples_total 8"), "{b}");
        assert!(b.contains("splitquant_qhealth_shadow_top1_agree_total 7"), "{b}");
        assert!(b.contains("splitquant_qhealth_shadow_kl_micro_nats{quantile=\"1\"} 40"), "{b}");
        for line in b.lines() {
            assert!(
                line.starts_with('#') || line.starts_with("splitquant_"),
                "stray line: {line}"
            );
        }
    }
}
