//! Per-thread event ring: a bounded single-producer buffer with seqlock
//! slots.
//!
//! Each recording thread owns one [`Ring`]. The owning thread is the only
//! writer; the drainer (serialized by the registry mutex in
//! [`crate::trace`]) may read concurrently. Writers never block and never
//! allocate after construction: when the ring is full the oldest events are
//! overwritten ("drop-oldest") and the drain reports how many were lost.
//!
//! Each slot carries a sequence stamp derived from the *monotonic* write
//! position `p` (not the wrapped index): `2p + 1` while a write is in
//! progress, `2p + 2` once complete. A reader that observes anything other
//! than the expected completed stamp for the position it wants — before or
//! after copying the payload — discards the copy and counts the event as
//! dropped, so torn reads are never surfaced.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicU64, Ordering};

use super::Event;

/// Default per-thread ring capacity in events (power of two).
pub const DEFAULT_CAPACITY: usize = 8192;

struct Slot {
    /// Seqlock stamp: `2p + 1` = write to position `p` in progress,
    /// `2p + 2` = position `p` committed, `0` = never written.
    seq: AtomicU64,
    data: UnsafeCell<MaybeUninit<Event>>,
}

/// Bounded single-producer event buffer with drop-oldest overflow.
pub struct Ring {
    slots: Box<[Slot]>,
    /// `capacity - 1`; capacity is always a power of two.
    mask: u64,
    /// Monotonic count of events ever pushed (next write position).
    head: AtomicU64,
    /// Monotonic count of events already consumed by [`Ring::drain`].
    tail: AtomicU64,
}

// SAFETY: the `UnsafeCell` payload is only written by the single owning
// thread (`push` is reached exclusively through a thread-local handle) and
// only read by `drain` under the seqlock protocol above: every racy read is
// copied into a `MaybeUninit` and validated against the slot's sequence
// stamp before being assumed initialized, so a torn or concurrent read is
// discarded rather than observed.
unsafe impl Sync for Ring {}
// SAFETY: all fields are plain data (atomics, `Event` is `Copy + 'static`);
// moving a `Ring` between threads does not invalidate the protocol above.
unsafe impl Send for Ring {}

impl Ring {
    /// Create a ring holding `capacity` events (rounded up to a power of
    /// two, minimum 2).
    pub fn with_capacity(capacity: usize) -> Ring {
        let cap = capacity.max(2).next_power_of_two();
        let slots = (0..cap)
            .map(|_| Slot { seq: AtomicU64::new(0), data: UnsafeCell::new(MaybeUninit::uninit()) })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Ring { slots, mask: (cap - 1) as u64, head: AtomicU64::new(0), tail: AtomicU64::new(0) }
    }

    /// Number of slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Append one event, overwriting the oldest if the ring is full.
    ///
    /// Must only be called by the thread that owns this ring.
    pub fn push(&self, ev: Event) {
        let p = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(p & self.mask) as usize];
        // The acquire side of the swap keeps the payload write below from
        // being reordered before the in-progress stamp becomes visible.
        slot.seq.swap(2 * p + 1, Ordering::AcqRel);
        // SAFETY: single producer — only the owning thread writes this cell,
        // and concurrent drains validate the stamp before trusting the data.
        unsafe { std::ptr::write_volatile(slot.data.get(), MaybeUninit::new(ev)) };
        slot.seq.store(2 * p + 2, Ordering::Release);
        self.head.store(p + 1, Ordering::Release);
    }

    /// Copy every undrained, still-valid event into `out` (oldest first) and
    /// advance the read cursor. Returns how many events were dropped — lost
    /// to overwrite before this drain, or torn by a concurrent overwrite
    /// during it.
    ///
    /// Callers must serialize drains (the registry mutex does this); the
    /// producer may keep pushing concurrently.
    pub fn drain(&self, out: &mut Vec<Event>) -> u64 {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        let mut dropped = 0u64;
        if head - tail > cap {
            // overwritten before we got here: drop-oldest accounting
            dropped += head - tail - cap;
            tail = head - cap;
        }
        while tail < head {
            let slot = &self.slots[(tail & self.mask) as usize];
            let want = 2 * tail + 2;
            if slot.seq.load(Ordering::Acquire) == want {
                // SAFETY: the copy may race with a wrapping writer; it stays
                // a `MaybeUninit` until the stamp re-check below proves the
                // slot was stable across the read.
                let data = unsafe { std::ptr::read_volatile(slot.data.get()) };
                fence(Ordering::Acquire);
                if slot.seq.load(Ordering::Relaxed) == want {
                    // SAFETY: the stamp held the committed value for this
                    // exact position before and after the copy, so the copy
                    // is a fully initialized `Event`.
                    out.push(unsafe { data.assume_init() });
                } else {
                    dropped += 1; // torn by a concurrent overwrite
                }
            } else {
                dropped += 1; // overwritten (or mid-write) before the read
            }
            tail += 1;
        }
        self.tail.store(head, Ordering::Relaxed);
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Category, EventKind};

    fn ev(i: u64) -> Event {
        Event { kind: EventKind::Instant, cat: Category::Kernel, name: "t", ts_ns: i, a: i, b: 0 }
    }

    #[test]
    fn push_drain_preserves_order() {
        let r = Ring::with_capacity(8);
        for i in 0..5 {
            r.push(ev(i));
        }
        let mut out = Vec::new();
        assert_eq!(r.drain(&mut out), 0);
        assert_eq!(out.iter().map(|e| e.a).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        out.clear();
        assert_eq!(r.drain(&mut out), 0, "second drain is empty");
        assert!(out.is_empty());
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let r = Ring::with_capacity(8);
        assert_eq!(r.capacity(), 8);
        for i in 0..20 {
            r.push(ev(i));
        }
        let mut out = Vec::new();
        let dropped = r.drain(&mut out);
        assert_eq!(dropped, 12, "20 pushed into 8 slots loses 12");
        assert_eq!(out.iter().map(|e| e.a).collect::<Vec<_>>(), (12..20).collect::<Vec<_>>());
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(Ring::with_capacity(0).capacity(), 2);
        assert_eq!(Ring::with_capacity(5).capacity(), 8);
        assert_eq!(Ring::with_capacity(8).capacity(), 8);
    }

    #[test]
    fn drain_between_overflows_accumulates() {
        let r = Ring::with_capacity(4);
        for i in 0..6 {
            r.push(ev(i));
        }
        let mut out = Vec::new();
        assert_eq!(r.drain(&mut out), 2);
        for i in 6..8 {
            r.push(ev(i));
        }
        out.clear();
        assert_eq!(r.drain(&mut out), 0);
        assert_eq!(out.iter().map(|e| e.a).collect::<Vec<_>>(), vec![6, 7]);
    }

    #[test]
    fn concurrent_writer_and_drainer_never_tear() {
        let r = std::sync::Arc::new(Ring::with_capacity(16));
        let w = std::sync::Arc::clone(&r);
        let writer = std::thread::spawn(move || {
            for i in 0..10_000 {
                w.push(ev(i));
            }
        });
        let mut seen = 0u64;
        let mut dropped = 0u64;
        let mut out = Vec::new();
        while !writer.is_finished() {
            out.clear();
            dropped += r.drain(&mut out);
            for e in &out {
                // payload invariant from `ev`: a mirrors ts_ns
                assert_eq!(e.a, e.ts_ns, "torn event surfaced");
            }
            seen += out.len() as u64;
        }
        writer.join().expect("writer thread");
        out.clear();
        dropped += r.drain(&mut out);
        seen += out.len() as u64;
        assert_eq!(seen + dropped, 10_000, "every push is seen or counted dropped");
    }
}
