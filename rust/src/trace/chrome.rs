//! Chrome trace-event JSON exporter (loads in `chrome://tracing` and
//! Perfetto).
//!
//! Renders a [`Snapshot`] as the standard `{"traceEvents": [...]}` object
//! format. Field ordering is deterministic: every event object is a
//! [`Json::Obj`] (a `BTreeMap`, so keys serialize sorted) and events are
//! emitted in a fixed traversal order (threads in registration order,
//! events oldest-first), satisfying the `deterministic-iteration` lint
//! contract — exporting the same snapshot twice yields byte-identical
//! output.
//!
//! Mapping:
//!
//! * `Enter`/`Exit` pairs are stack-matched per thread into `"ph":"X"`
//!   complete events (an unclosed `Enter` becomes an `X` running to the end
//!   of the snapshot with `"unfinished": true`; an `Exit` whose `Enter` was
//!   lost to ring overflow is dropped).
//! * `Instant` → `"ph":"i"` (thread scope), `Counter` → `"ph":"C"`.
//! * `Complete` → `"ph":"X"` directly; request-lifecycle slices
//!   ([`Category::Request`]) are parked on a synthetic per-lane track
//!   (`tid = 1000 + lane`) so each batch lane renders as its own row.

use std::path::Path;

use super::{Category, Event, EventKind, Snapshot};
use crate::error::Result;
use crate::util::json::{obj, Json};

/// Synthetic tid base for per-lane request-lifecycle tracks.
const LANE_TID_BASE: usize = 1000;

fn us(ns: u64) -> Json {
    Json::from(ns as f64 / 1000.0)
}

fn args2(a: u64, b: u64) -> Json {
    obj(vec![("a", Json::from(a as f64)), ("b", Json::from(b as f64))])
}

fn base(ev: &Event, ph: &str, tid: usize) -> Vec<(&'static str, Json)> {
    vec![
        ("cat", Json::from(ev.cat.as_str())),
        ("name", Json::from(ev.name)),
        ("ph", Json::from(ph.to_string())),
        ("pid", Json::from(1usize)),
        ("tid", Json::from(tid)),
        ("ts", us(ev.ts_ns)),
    ]
}

/// Render a snapshot as the Chrome trace-event JSON object.
pub fn chrome_trace(snap: &Snapshot) -> Json {
    let end_ns = snap
        .threads
        .iter()
        .flat_map(|(_, evs)| evs.iter())
        .map(|e| e.ts_ns.max(e.ts_ns.saturating_add(e.a)))
        .max()
        .unwrap_or(0);
    let mut events: Vec<Json> = Vec::new();
    for (tid, (name, evs)) in snap.threads.iter().enumerate() {
        events.push(obj(vec![
            ("args", obj(vec![("name", Json::from(name.as_str()))])),
            ("name", Json::from("thread_name")),
            ("ph", Json::from("M")),
            ("pid", Json::from(1usize)),
            ("tid", Json::from(tid)),
        ]));
        let mut open: Vec<&Event> = Vec::new();
        for ev in evs {
            match ev.kind {
                EventKind::Enter => open.push(ev),
                EventKind::Exit => {
                    // an Exit with no open Enter lost its opener to ring
                    // overflow; drop it rather than fabricate a span
                    if let Some(enter) = open.pop() {
                        let mut fields = base(enter, "X", tid);
                        fields.push(("dur", us(ev.ts_ns.saturating_sub(enter.ts_ns))));
                        fields.push(("args", args2(enter.a, enter.b)));
                        events.push(obj(fields));
                    }
                }
                EventKind::Instant => {
                    let mut fields = base(ev, "i", tid);
                    fields.push(("s", Json::from("t")));
                    fields.push(("args", args2(ev.a, ev.b)));
                    events.push(obj(fields));
                }
                EventKind::Counter => {
                    let mut fields = base(ev, "C", tid);
                    fields.push(("args", obj(vec![("value", Json::from(ev.a as f64))])));
                    events.push(obj(fields));
                }
                EventKind::Complete => {
                    let lane_tid = if ev.cat == Category::Request {
                        LANE_TID_BASE + ev.b as usize
                    } else {
                        tid
                    };
                    let mut fields = base(ev, "X", lane_tid);
                    fields.push(("dur", us(ev.a)));
                    fields.push(("args", obj(vec![("lane", Json::from(ev.b as f64))])));
                    events.push(obj(fields));
                }
            }
        }
        // spans still open when the snapshot was taken: render them as
        // running to the end of the trace and mark them unfinished
        for enter in open {
            let mut fields = base(enter, "X", tid);
            fields.push(("dur", us(end_ns.saturating_sub(enter.ts_ns))));
            fields.push((
                "args",
                obj(vec![
                    ("a", Json::from(enter.a as f64)),
                    ("b", Json::from(enter.b as f64)),
                    ("unfinished", Json::from(true)),
                ]),
            ));
            events.push(obj(fields));
        }
    }
    obj(vec![
        ("displayTimeUnit", Json::from("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Render a snapshot as a compact Chrome trace-event JSON string.
pub fn chrome_trace_string(snap: &Snapshot) -> String {
    chrome_trace(snap).to_string()
}

/// Write the Chrome trace JSON for `snap` to `path`.
pub fn write_chrome_trace(path: &Path, snap: &Snapshot) -> Result<()> {
    std::fs::write(path, chrome_trace_string(snap))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, cat: Category, name: &'static str, ts: u64, a: u64, b: u64) -> Event {
        Event { kind, cat, name, ts_ns: ts, a, b }
    }

    fn sample() -> Snapshot {
        Snapshot {
            threads: vec![
                (
                    "worker-0".to_string(),
                    vec![
                        ev(EventKind::Enter, Category::Batch, "execute", 1_000, 4, 8),
                        ev(EventKind::Enter, Category::Kernel, "matmul-chunk", 2_000, 0, 16),
                        ev(EventKind::Exit, Category::Kernel, "matmul-chunk", 5_000, 0, 0),
                        ev(EventKind::Exit, Category::Batch, "execute", 9_000, 0, 0),
                        ev(EventKind::Complete, Category::Request, "req-total", 500, 9_000, 2),
                        ev(EventKind::Instant, Category::Shard, "shard-evict", 9_500, 3, 0),
                        ev(EventKind::Counter, Category::Kernel, "pool_tasks", 9_600, 7, 0),
                    ],
                ),
                (
                    "loner".to_string(),
                    vec![
                        // orphan Exit (Enter lost to overflow) + unfinished Enter
                        ev(EventKind::Exit, Category::Batch, "pad", 100, 0, 0),
                        ev(EventKind::Enter, Category::Autotune, "sweep", 200, 0, 0),
                    ],
                ),
            ],
            dropped: 1,
        }
    }

    #[test]
    fn export_is_valid_json_with_expected_events() {
        let s = chrome_trace_string(&sample());
        let parsed = Json::parse(&s).expect("exporter must emit parseable JSON");
        let evs = parsed
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        // 2 metadata + 2 matched X + 1 Complete X + 1 instant + 1 counter
        // + 1 unfinished X; the orphan Exit is dropped
        assert_eq!(evs.len(), 8, "{s}");
        for e in &evs {
            assert!(e.has("ph") && e.has("pid") && e.has("tid"), "{s}");
        }
        // nested span: inner chunk X has ts 2.0us dur 3.0us
        assert!(s.contains("\"name\":\"matmul-chunk\""), "{s}");
        assert!(s.contains("\"dur\":3"), "{s}");
        // request Complete lands on the synthetic lane track
        assert!(s.contains(&format!("\"tid\":{}", LANE_TID_BASE + 2)), "{s}");
        // unfinished span is flagged
        assert!(s.contains("\"unfinished\":true"), "{s}");
    }

    #[test]
    fn export_is_byte_deterministic() {
        let snap = sample();
        assert_eq!(chrome_trace_string(&snap), chrome_trace_string(&snap));
    }

    #[test]
    fn field_order_is_sorted_within_each_event() {
        let s = chrome_trace_string(&sample());
        // Obj is a BTreeMap: "args" < "cat" < ... < "ts" in every event
        let first_event = s.find("\"cat\"").expect("has events");
        let args = s.find("\"args\"").expect("has args");
        assert!(args < first_event, "keys serialize sorted: {s}");
    }

    #[test]
    fn empty_snapshot_exports_empty_array() {
        let s = chrome_trace_string(&Snapshot::default());
        assert_eq!(s, "{\"displayTimeUnit\":\"ms\",\"traceEvents\":[]}");
    }
}
