//! PJRT CPU client + executable cache.
//!
//! Pattern from `/opt/xla-example/load_hlo`: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Compilation happens once per executable and
//! is cached; `run` is the request-path entry point.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::literal::{from_literal, to_literal, Value};
use super::manifest::{ExeSpec, Manifest};

/// A compiled executable plus its I/O spec.
pub struct LoadedExe {
    pub spec: ExeSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedExe {
    /// Execute with typed values; returns outputs in spec order.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: got {} inputs, expected {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&self.spec.inputs)
            .map(|(v, s)| to_literal(v, s))
            .collect::<Result<Vec<_>>>()?;
        self.run_literals(&literals)
    }

    /// Execute with raw literals (callers that pre-stage literals, e.g. the
    /// i8 planes of the split-linear kernel).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Value>> {
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_literal_refs(&refs)
    }

    /// Execute with **borrowed** literals. This is the zero-copy request
    /// path: callers stage their constant inputs (parameter literals) once
    /// and assemble each call as references to the staged values plus the
    /// per-request literals — nothing staged is cloned or re-converted
    /// (see [`crate::coordinator::PjrtExecutor`]).
    pub fn run_literal_refs(&self, literals: &[&xla::Literal]) -> Result<Vec<Value>> {
        let result = self.exe.execute(literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: outputs arrive as one tuple
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "{}: got {} outputs, expected {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            )));
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(l, s)| from_literal(l, s))
            .collect()
    }

    /// Single-f32-output convenience over [`Self::run_literal_refs`].
    pub fn run_f32_refs(&self, literals: &[&xla::Literal]) -> Result<Tensor> {
        self.single_f32(self.run_literal_refs(literals)?)
    }

    /// Unwrap the one-f32-output convention shared by the forward passes.
    fn single_f32(&self, mut out: Vec<Value>) -> Result<Tensor> {
        if out.len() != 1 {
            return Err(Error::Runtime(format!(
                "{}: expected 1 output, got {}",
                self.spec.name,
                out.len()
            )));
        }
        out.remove(0).into_f32()
    }

    /// Convenience for single-f32-output executables (forward passes).
    pub fn run_f32(&self, inputs: &[Value]) -> Result<Tensor> {
        self.single_f32(self.run(inputs)?)
    }
}

/// PJRT runtime: client + manifest + compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<LoadedExe>>>,
}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest.validate_abi()?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Load (compile) an executable by manifest name; cached.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedExe>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.exe(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::info!("compiled {name} in {:?}", t0.elapsed());
        let loaded = Arc::new(LoadedExe { spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

// The underlying PJRT client handles are internally synchronized; the Rust
// wrapper types just hold opaque pointers.

// SAFETY: a `LoadedExe` owns only the immutable input spec plus an opaque
// PJRT executable handle; PJRT executables may be invoked from any thread.
unsafe impl Send for LoadedExe {}
// SAFETY: shared references only read the immutable spec and call the
// internally-synchronized PJRT execute entry point.
unsafe impl Sync for LoadedExe {}
// SAFETY: the PJRT client handle is internally synchronized and the compile
// cache sits behind its own `Mutex`; nothing is thread-affine.
unsafe impl Send for Runtime {}
// SAFETY: every `&self` method either locks the cache mutex or calls an
// internally-synchronized PJRT entry point.
unsafe impl Sync for Runtime {}
