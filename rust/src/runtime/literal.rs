//! Tensor ⇄ `xla::Literal` conversion.

use crate::error::{Error, Result};
use crate::tensor::{IntTensor, Tensor};

use super::manifest::{Dtype, IoSpec};

/// Either element type, as fed to / returned from an executable.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(_) => Dtype::F32,
            Value::I32(_) => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => Err(Error::Runtime("expected f32 value".into())),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => Err(Error::Runtime("expected f32 value".into())),
        }
    }

    pub fn into_i32(self) -> Result<IntTensor> {
        match self {
            Value::I32(t) => Ok(t),
            _ => Err(Error::Runtime("expected i32 value".into())),
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Self {
        Value::I32(t)
    }
}

fn dims_i64(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|&d| d as i64).collect()
}

fn check_slot(shape: &[usize], dtype: Dtype, spec: &IoSpec) -> Result<()> {
    if shape != spec.shape.as_slice() {
        return Err(Error::Runtime(format!(
            "input {:?}: shape {:?} does not match spec {:?}",
            spec.name, shape, spec.shape
        )));
    }
    if dtype != spec.dtype {
        return Err(Error::Runtime(format!(
            "input {:?}: dtype {:?} does not match spec {:?}",
            spec.name, dtype, spec.dtype
        )));
    }
    Ok(())
}

/// Convert an f32 tensor to a literal for the slot `spec` (no owned
/// [`Value`] required — used to stage parameter literals once).
pub fn f32_literal(t: &Tensor, spec: &IoSpec) -> Result<xla::Literal> {
    check_slot(t.shape(), Dtype::F32, spec)?;
    Ok(xla::Literal::vec1(t.data()).reshape(&dims_i64(t.shape()))?)
}

/// Convert an i32 tensor to a literal for the slot `spec`.
pub fn i32_literal(t: &IntTensor, spec: &IoSpec) -> Result<xla::Literal> {
    check_slot(t.shape(), Dtype::I32, spec)?;
    Ok(xla::Literal::vec1(t.data()).reshape(&dims_i64(t.shape()))?)
}

/// Convert a value to a literal, checking it against the slot spec.
pub fn to_literal(v: &Value, spec: &IoSpec) -> Result<xla::Literal> {
    match v {
        Value::F32(t) => f32_literal(t, spec),
        Value::I32(t) => i32_literal(t, spec),
    }
}

/// Convert a returned literal into a [`Value`] following the output spec.
pub fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<Value> {
    match spec.dtype {
        Dtype::F32 => {
            let data = lit.to_vec::<f32>()?;
            Ok(Value::F32(Tensor::new(&spec.shape, data)?))
        }
        Dtype::I32 => {
            let data = lit.to_vec::<i32>()?;
            Ok(Value::I32(IntTensor::new(&spec.shape, data)?))
        }
        Dtype::I8 => {
            // i8 outputs are converted to i32 tensors for convenience
            let conv = lit.convert(xla::PrimitiveType::S32)?;
            let data = conv.to_vec::<i32>()?;
            Ok(Value::I32(IntTensor::new(&spec.shape, data)?))
        }
    }
}

/// Pack an i8 plane (codes / cluster ids) for an i8 input slot.
pub fn i8_literal(data: &[i8], shape: &[usize], spec: &IoSpec) -> Result<xla::Literal> {
    if shape != spec.shape.as_slice() || spec.dtype != Dtype::I8 {
        return Err(Error::Runtime(format!(
            "i8 input {:?}: shape {shape:?} vs spec {:?} ({:?})",
            spec.name, spec.shape, spec.dtype
        )));
    }
    // xla::Literal has no i8 NativeType constructor in this crate version;
    // go through i32 and convert.
    let as_i32: Vec<i32> = data.iter().map(|&v| v as i32).collect();
    let lit = xla::Literal::vec1(&as_i32).reshape(&dims_i64(shape))?;
    Ok(lit.convert(xla::PrimitiveType::S8)?)
}
