//! PJRT runtime bridge: loads the AOT-compiled HLO-text artifacts and runs
//! them from Rust. Python never executes at runtime — the artifacts are the
//! only L2 output the coordinator consumes.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (the L2⇄L3 ABI).
//! * [`literal`] — [`crate::tensor`] ⇄ `xla::Literal` conversion.
//! * [`client`] — PJRT CPU client, executable cache, typed `run` calls.

pub mod client;
pub mod literal;
pub mod manifest;

pub use client::{LoadedExe, Runtime};
pub use manifest::{Dtype, ExeSpec, IoSpec, Manifest};

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
