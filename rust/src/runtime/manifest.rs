//! `artifacts/manifest.json` — the ABI between the build-time Python layers
//! and the Rust runtime: executable inventory, I/O specs, parameter order,
//! activation-site table and model configs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::model::config::{BertConfig, CnnConfig};
use crate::util::json::Json;

/// Element type of an executable input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    I8,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "i8" => Ok(Dtype::I8),
            _ => Err(Error::Manifest(format!("unknown dtype {s:?}"))),
        }
    }
}

/// One input or output slot.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT executable.
#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub executables: BTreeMap<String, ExeSpec>,
    pub bert: BertConfig,
    pub cnn: CnnConfig,
    pub bert_param_order: Vec<(String, Vec<usize>)>,
    pub cnn_param_order: Vec<(String, Vec<usize>)>,
    /// (site name, width, interior chunk bounds)
    pub act_sites: Vec<(String, usize, Vec<usize>)>,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    let shape = j
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<Vec<_>>>()?;
    Ok(IoSpec {
        name: j.get("name")?.as_str()?.to_string(),
        shape,
        dtype: Dtype::parse(j.get("dtype")?.as_str()?)?,
    })
}

fn parse_order(j: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            let pair = e.as_arr()?;
            let name = pair[0].as_str()?.to_string();
            let shape =
                pair[1].as_arr()?.iter().map(|d| d.as_usize()).collect::<Result<Vec<_>>>()?;
            Ok((name, shape))
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {path:?}: {e} — run `make artifacts` first"
            ))
        })?;
        let j = Json::parse(&text)?;

        let mut executables = BTreeMap::new();
        for (name, entry) in j.get("executables")?.as_obj()? {
            let spec = ExeSpec {
                name: name.clone(),
                file: entry.get("file")?.as_str()?.to_string(),
                inputs: entry
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<Vec<_>>>()?,
                outputs: entry
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<Vec<_>>>()?,
            };
            if !dir.join(&spec.file).exists() {
                return Err(Error::Manifest(format!(
                    "executable {name}: file {} missing from {dir:?}",
                    spec.file
                )));
            }
            executables.insert(name.clone(), spec);
        }

        let act_sites = j
            .get("act_sites")?
            .as_arr()?
            .iter()
            .map(|e| {
                let bounds = e
                    .get("bounds")?
                    .as_arr()?
                    .iter()
                    .map(|b| b.as_usize())
                    .collect::<Result<Vec<_>>>()?;
                Ok((e.get("name")?.as_str()?.to_string(), e.get("width")?.as_usize()?, bounds))
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            executables,
            bert: BertConfig::from_manifest(&j)?,
            cnn: CnnConfig::from_manifest(&j)?,
            bert_param_order: parse_order(j.get("bert_param_order")?)?,
            cnn_param_order: parse_order(j.get("cnn_param_order")?)?,
            act_sites,
        })
    }

    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no executable {name:?} in manifest")))
    }

    /// Cross-check the manifest's parameter order against the Rust config —
    /// the drift guard between `config.py` and `model::config`.
    pub fn validate_abi(&self) -> Result<()> {
        let rust_order = self.bert.param_order();
        if rust_order != self.bert_param_order {
            return Err(Error::Manifest(
                "bert param order mismatch between manifest and rust config".into(),
            ));
        }
        let rust_cnn = self.cnn.param_order();
        if rust_cnn != self.cnn_param_order {
            return Err(Error::Manifest(
                "cnn param order mismatch between manifest and rust config".into(),
            ));
        }
        let sites = self.bert.act_sites();
        if sites.len() != self.act_sites.len()
            || sites
                .iter()
                .zip(&self.act_sites)
                .any(|((n1, w1), (n2, w2, _))| n1 != n2 || w1 != w2)
        {
            return Err(Error::Manifest("activation site table mismatch".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn load_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.executables.contains_key("bert_fwd_b32"));
        assert!(m.executables.contains_key("bert_train_step_b32"));
        m.validate_abi().unwrap();
        let fwd = m.exe("bert_fwd_b32").unwrap();
        assert_eq!(fwd.inputs.len(), m.bert_param_order.len() + 2);
        assert_eq!(fwd.outputs[0].shape, vec![32, m.bert.num_classes]);
        assert_eq!(fwd.inputs[0].dtype, Dtype::F32);
    }

    #[test]
    fn missing_dir_is_friendly() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
