//! Integration: the parallel kernel engine under the serving coordinator.
//!
//! Runs entirely on the pure-Rust executor (no artifacts needed): mixed
//! request sizes flow through the Condvar batcher, pad to compiled batch
//! shapes, and execute on the shared worker pool — labels must match
//! direct single-request inference exactly, and the kernel engine must
//! agree with the serial kernels at model scale.

use std::sync::Arc;
use std::time::Duration;

use splitquant::coordinator::{RustExecutor, ServeConfig, Server};
use splitquant::data::HashTokenizer;
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::model::BertModel;
use splitquant::parallel::{kernels, ParallelConfig};
use splitquant::tensor::{ops, IntTensor, Tensor};
use splitquant::util::rng::Rng;

/// Force every matmul in this test binary through the worker pool (the
/// tiny test model would otherwise stay under the serial-fallback
/// threshold). Process-wide and first-wins, so each test calls it.
fn force_parallel() {
    splitquant::parallel::configure(ParallelConfig {
        threads: 4,
        serial_flops: 1,
        ..ParallelConfig::default()
    });
}

fn tiny_cfg() -> BertConfig {
    BertConfig {
        vocab_size: 512,
        hidden: 32,
        layers: 2,
        heads: 2,
        ffn: 64,
        max_len: 16,
        num_classes: 5,
        ln_eps: 1e-12,
    }
}

#[test]
fn mixed_request_sizes_serve_correct_labels_on_shared_pool() {
    force_parallel();
    let cfg = tiny_cfg();
    let mut rng = Rng::new(42);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let model = BertModel::new(cfg.clone(), store.clone()).unwrap();

    // requests of very different lengths → different padding per batch
    let texts: Vec<String> = (0..40)
        .map(|i| {
            let words = 1 + (i * 7) % 13;
            (0..words).map(|w| format!("tok{i}x{w}")).collect::<Vec<_>>().join(" ")
        })
        .collect();

    // direct labels, one request at a time through the serial-ish path
    let direct: Vec<i32> = texts
        .iter()
        .map(|t| {
            let (ids, mask) = tok.encode(t);
            let ids = IntTensor::new(&[1, cfg.max_len], ids).unwrap();
            let mask = Tensor::new(&[1, cfg.max_len], mask).unwrap();
            model.predict(&ids, &mask)[0]
        })
        .collect();

    let ex = Arc::new(RustExecutor::new(cfg, store, vec![1, 4, 8]).unwrap());
    let server = Server::start(
        ex,
        tok,
        ServeConfig {
            max_wait: Duration::from_millis(1),
            workers: 3, // three serving workers share ONE kernel pool
            queue_cap: 256,
            parallel: ParallelConfig::default(),
            ..ServeConfig::default()
        },
    );
    let rxs: Vec<_> = texts.iter().map(|t| server.submit(t).unwrap()).collect();
    let served: Vec<i32> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap().label)
        .collect();
    let m = server.shutdown();
    assert_eq!(direct, served, "batched+padded+parallel labels must match direct");
    assert_eq!(m.completed, 40);
    // the padding-overhead cap must hold end to end
    let executed = m.real_slots + m.padded_slots;
    assert!(
        (executed as f64) <= 2.0 * m.real_slots as f64,
        "padding overhead: executed {executed} slots for {} real",
        m.real_slots
    );
}

#[test]
fn parallel_kernels_match_serial_at_model_scale() {
    force_parallel();
    // the acceptance shapes: big enough to cross the dispatch threshold
    let mut rng = Rng::new(7);
    let a = Tensor::randn(&[512, 512], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[512, 512], 0.0, 1.0, &mut rng);
    let par = kernels::matmul(&a, &b);
    let ser = ops::matmul_serial(&a, &b);
    assert!(par.max_abs_diff(&ser) <= 1e-5, "matmul gap {}", par.max_abs_diff(&ser));

    let a3 = Tensor::randn(&[16, 48, 32], 0.0, 1.0, &mut rng);
    let b3 = Tensor::randn(&[16, 32, 40], 0.0, 1.0, &mut rng);
    let par3 = kernels::batch_matmul(&a3, &b3);
    let ser3 = ops::batch_matmul_serial(&a3, &b3);
    assert!(par3.max_abs_diff(&ser3) <= 1e-5, "batch gap {}", par3.max_abs_diff(&ser3));
}

#[test]
fn kernel_engines_are_bit_identical_at_model_scale() {
    use splitquant::parallel::KernelKind;
    force_parallel();
    // ragged model-scale shapes: every engine × dispatch combination must
    // produce the same bits, not just the same floats to tolerance
    let mut rng = Rng::new(9);
    let a = Tensor::randn(&[257, 129], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[129, 201], 0.0, 1.0, &mut rng);
    let base = ops::matmul_serial_with(&a, &b, KernelKind::Scalar);
    for (label, got) in [
        ("serial-simd", ops::matmul_serial_with(&a, &b, KernelKind::Simd)),
        ("pooled-scalar", kernels::matmul_with(&a, &b, KernelKind::Scalar)),
        ("pooled-simd", kernels::matmul_with(&a, &b, KernelKind::Simd)),
    ] {
        assert_eq!(base.data(), got.data(), "{label} diverged");
    }
}

#[test]
fn quantized_forward_agrees_between_pool_and_serial_paths() {
    use splitquant::model::QuantizedBert;
    use splitquant::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};

    force_parallel();
    let cfg = tiny_cfg();
    let mut rng = Rng::new(3);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = default_quantizable(&store);
    let (eval_store, qm) =
        quantize_store(&store, &quantizable, &SplitQuantConfig::new(4)).unwrap();
    let reference = BertModel::new(cfg.clone(), eval_store).unwrap();
    let fused = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();

    // batch large enough that projections cross the parallel threshold in
    // bigger configs, small enough to stay fast here; the contract is that
    // dispatch choice never changes answers
    let b = 8;
    let ids: Vec<i32> =
        (0..b * cfg.max_len).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let ids = IntTensor::new(&[b, cfg.max_len], ids).unwrap();
    let mask = Tensor::full(&[b, cfg.max_len], 1.0);
    let gap =
        reference.forward(&ids, &mask).max_abs_diff(&fused.forward(&ids, &mask).unwrap());
    assert!(gap < 1e-3, "fused/parallel forward gap {gap}");
}
