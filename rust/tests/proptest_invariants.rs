//! Cross-module property tests: the invariants the paper's correctness
//! argument rests on, checked over randomized inputs (seeded, replayable via
//! SPLITQUANT_PROPTEST_SEED).

use splitquant::clustering;
use splitquant::coordinator::BatchPolicy;
use splitquant::model::config::chunk_spans;
use splitquant::model::graph::{ActKind, Layer};
use splitquant::quant::{qrange, QConfig, QParams, QTensor};
use splitquant::splitquant::weight_split::materialize_branches;
use splitquant::splitquant::{split_quantize, split_quantize_pair, SplitQuantConfig};
use splitquant::tensor::ops;
use splitquant::tensor::packing::Packed;
use splitquant::tensor::Tensor;
use splitquant::util::json::Json;
use splitquant::util::proptest::{check, gen_values_with_outliers};

#[test]
fn prop_split_linear_exactly_preserves_fp32_function() {
    // Figure 2: Σ_c x·(W ⊙ m_c) == x·W for any partition
    check("split linear identity", 40, |rng| {
        let (ni, no, m) = (rng.range(1, 40), rng.range(1, 30), rng.range(1, 6));
        let w = Tensor::randn(&[ni, no], 0.0, 1.0, rng);
        let b = Tensor::randn(&[no], 0.0, 1.0, rng);
        let cfg = SplitQuantConfig::new(4);
        let (ws, bs) = split_quantize_pair(&w, Some(&b), &cfg, rng).unwrap();
        let bs = bs.unwrap();
        let split = splitquant::splitquant::equivalence::split_linear_layer(
            &w,
            Some(&b),
            &ws,
            Some(&bs),
            cfg.k,
        );
        let orig = Layer::Linear { weight: w, bias: Some(b) };
        let x = Tensor::randn(&[m, ni], 0.0, 1.0, rng);
        let gap = orig.forward(&x).max_abs_diff(&split.forward(&x));
        assert!(gap < 1e-4, "gap {gap}");
    });
}

#[test]
fn prop_split_activation_identity() {
    // Figure 1 (D): chunk → activate → concat == activate
    check("split activation identity", 40, |rng| {
        let w = rng.range(3, 200);
        let r = rng.range(1, 10);
        let x = Tensor::randn(&[r, w], 0.0, 3.0, rng);
        for kind in [ActKind::Relu, ActKind::Gelu, ActKind::Tanh] {
            let plain = Layer::Activation(kind).forward(&x);
            let split =
                Layer::SplitActivation { kind, spans: chunk_spans(w, 3) }.forward(&x);
            assert!(plain.max_abs_diff(&split) < 1e-6);
        }
    });
}

#[test]
fn prop_split_quantization_never_worse_than_baseline_mse() {
    // per-cluster scales subdivide the range ⇒ reconstruction can only improve
    check("split >= baseline reconstruction", 30, |rng| {
        let n = rng.range(16, 600);
        let vals = gen_values_with_outliers(rng, n, 0.05);
        let t = Tensor::new(&[n], vals).unwrap();
        let bits = [2u8, 4][rng.below(2)];
        let st = split_quantize(&t, &SplitQuantConfig::new(bits), rng).unwrap();
        let sq = st.qtensor.dequantize();
        let base = QTensor::quantize(&t, &QConfig::baseline(bits)).unwrap().dequantize();
        let mse = |a: &Tensor| -> f64 {
            a.data()
                .iter()
                .zip(t.data())
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum()
        };
        // allow a tiny epsilon: k-means is heuristic, ties can flip codes
        assert!(
            mse(&sq) <= mse(&base) * 1.05 + 1e-9,
            "split {} vs base {}",
            mse(&sq),
            mse(&base)
        );
    });
}

#[test]
fn prop_injected_zeros_reconstruct_exactly() {
    // the zero-injection trick is only sound because dq(Q(0)) == 0
    check("zeros exact through split quant", 40, |rng| {
        let n = rng.range(4, 300);
        let vals = gen_values_with_outliers(rng, n, 0.1);
        let t = Tensor::new(&[n], vals).unwrap();
        let bits = [2u8, 4, 8][rng.below(3)];
        let st = split_quantize(&t, &SplitQuantConfig::new(bits), rng).unwrap();
        for p in st.qtensor.params() {
            assert_eq!(p.fake(0.0), 0.0, "params {p:?}");
        }
    });
}

#[test]
fn prop_packing_roundtrip_any_width() {
    check("packing roundtrip", 60, |rng| {
        let bits = [1u8, 2, 4, 8][rng.below(4)];
        let (qmin, qmax) = qrange(bits);
        let n = rng.range(1, 500);
        let codes: Vec<i8> = (0..n)
            .map(|_| (qmin + rng.below((qmax - qmin + 1) as usize) as i32) as i8)
            .collect();
        let p = Packed::pack(&codes, bits).unwrap();
        assert_eq!(p.unpack(), codes);
        assert_eq!(p.byte_size(), n.div_ceil(8 / bits as usize));
    });
}

#[test]
fn prop_quant_dequant_error_bound() {
    check("quant error bounded by half step in-range", 50, |rng| {
        let bits = [2u8, 4, 8][rng.below(3)];
        let lo = rng.normal_f32(0.0, 5.0);
        let hi = lo + rng.range_f64(0.1, 50.0) as f32;
        let p = QParams::from_range(lo, hi, bits);
        for _ in 0..30 {
            let x = lo + rng.f32() * (hi - lo);
            assert!((p.fake(x) - x).abs() <= p.step() * 0.501 + 1e-6);
        }
    });
}

#[test]
fn prop_kmeans_partition_is_voronoi() {
    check("kmeans assignment is nearest-centroid", 25, |rng| {
        let n = rng.range(8, 2000);
        let vals = gen_values_with_outliers(rng, n, 0.05);
        let k = rng.range(2, 5);
        let r = clustering::cluster(&vals, k, 40, rng);
        for (&v, &a) in vals.iter().zip(&r.assignment) {
            let d = (v - r.centroids[a as usize]).abs();
            for &c in &r.centroids {
                assert!(d <= (v - c).abs() + 1e-5);
            }
        }
    });
}

#[test]
fn prop_batch_policy_never_overflows_or_starves() {
    check("batch policy sanity", 50, |rng| {
        let mut sizes: Vec<usize> = (0..rng.range(1, 4)).map(|_| rng.range(1, 64)).collect();
        sizes.push(rng.range(1, 64));
        let policy = BatchPolicy::new(sizes, std::time::Duration::from_millis(2));
        let pending = rng.below(200);
        let age = std::time::Duration::from_millis(rng.below(10) as u64);
        match policy.decide(pending, age) {
            Some((take, size)) => {
                assert!(take >= 1 && take <= pending);
                assert!(size >= take || size == policy.max_batch());
                assert!(policy.sizes().contains(&size));
            }
            None => {
                // must only hold back when the queue is partial AND young
                assert!(
                    pending < policy.max_batch()
                        && (pending == 0 || age < policy.max_wait)
                );
            }
        }
    });
}

#[test]
fn prop_json_roundtrip_fuzz() {
    check("json value roundtrip", 40, |rng| {
        fn gen(rng: &mut splitquant::util::rng::Rng, depth: usize) -> Json {
            match if depth > 2 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.chance(0.5)),
                2 => Json::Num((rng.normal_f32(0.0, 100.0) as f64 * 100.0).round() / 100.0),
                3 => Json::Str(format!("s{}-\"x\"\n", rng.below(1000))),
                4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                        .collect(),
                ),
            }
        }
        let j = gen(rng, 0);
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    });
}

#[test]
fn prop_sum_of_materialized_branches_is_identity() {
    check("Σ branches == tensor", 40, |rng| {
        let n = rng.range(1, 500);
        let vals = gen_values_with_outliers(rng, n, 0.1);
        let t = Tensor::new(&[n], vals).unwrap();
        let st = split_quantize(&t, &SplitQuantConfig::new(2), rng).unwrap();
        let branches = materialize_branches(&t, &st.assignment, 3);
        let mut sum = Tensor::zeros(t.shape());
        for b in &branches {
            sum.add_assign(b);
        }
        assert_eq!(sum.data(), t.data());
    });
}

#[test]
fn prop_csr_matmul_matches_dense() {
    check("csr == dense matmul", 30, |rng| {
        let (m, k, n) = (rng.range(1, 12), rng.range(1, 40), rng.range(1, 30));
        let mut w = Tensor::randn(&[k, n], 0.0, 1.0, rng);
        for v in w.data_mut() {
            if rng.chance(0.7) {
                *v = 0.0;
            }
        }
        let x = Tensor::randn(&[m, k], 0.0, 1.0, rng);
        let dense = ops::matmul(&x, &w);
        let sparse = splitquant::model::sparse::CsrMatrix::from_dense(&w).matmul(&x);
        assert!(dense.max_abs_diff(&sparse) < 1e-4);
    });
}
