//! Integration: the serving coordinator end-to-end over PJRT executables.
//! Skipped when artifacts are absent.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use splitquant::coordinator::{PjrtExecutor, ServeConfig, Server};
use splitquant::data::{emotion, HashTokenizer};
use splitquant::model::params::ParamStore;
use splitquant::model::BertModel;
use splitquant::runtime::Runtime;
use splitquant::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_serving_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.bert.clone();
    let mut rng = Rng::new(0);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let exec = Arc::new(PjrtExecutor::new(&rt, &store, &[1, 8, 32]).unwrap());
    let server = Server::start(
        exec,
        tok,
        ServeConfig {
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_cap: 512,
            ..ServeConfig::default()
        },
    );

    let (_, pool) = emotion::load_small(0, 4, 64);
    let rxs: Vec<_> =
        (0..64).map(|i| server.submit(&pool.texts[i % pool.len()]).unwrap()).collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert!((0..cfg.num_classes as i32).contains(&r.label));
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 64);
    assert!(m.throughput() > 0.0);
}

#[test]
fn served_labels_match_direct_inference() {
    // the coordinator (batching, padding, threading) must not change answers
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.bert.clone();
    let mut rng = Rng::new(2);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let model = BertModel::new(cfg.clone(), store.clone()).unwrap();

    let (_, pool) = emotion::load_small(2, 4, 16);
    // direct labels via the rust executor
    let direct: Vec<i32> = pool
        .texts
        .iter()
        .map(|t| {
            let (ids, mask) = tok.encode(t);
            let ids = splitquant::tensor::IntTensor::new(&[1, cfg.max_len], ids).unwrap();
            let mask = splitquant::tensor::Tensor::new(&[1, cfg.max_len], mask).unwrap();
            model.predict(&ids, &mask)[0]
        })
        .collect();

    let exec = Arc::new(PjrtExecutor::new(&rt, &store, &[1, 8, 32]).unwrap());
    let server = Server::start(
        exec,
        tok,
        ServeConfig {
            max_wait: Duration::from_millis(1),
            workers: 2,
            queue_cap: 128,
            ..ServeConfig::default()
        },
    );
    let rxs: Vec<_> = pool.texts.iter().map(|t| server.submit(t).unwrap()).collect();
    let served: Vec<i32> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap().label)
        .collect();
    server.shutdown();
    assert_eq!(direct, served);
}
