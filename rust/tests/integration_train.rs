//! Integration: training through the AOT fused train-step executables.
//! Skipped when artifacts are absent.

use std::path::{Path, PathBuf};

use splitquant::data::{emotion, HashTokenizer, TextBatcher};
use splitquant::model::params::ParamStore;
use splitquant::runtime::Runtime;
use splitquant::train::{LrSchedule, Trainer};
use splitquant::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn bert_training_reduces_loss() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.bert.clone();
    let (train, _) = emotion::load_small(0, 512, 8);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let mut batcher = TextBatcher::new(&train, &tok, 32);
    let mut rng = Rng::new(0);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let mut trainer = Trainer::new(&rt, "bert_train_step_b32", store).unwrap();
    let losses = trainer
        .train_text(
            &mut batcher,
            60,
            &LrSchedule::Constant(2e-3),
            &mut rng,
            0,
            |_| {},
        )
        .unwrap();
    let head: f32 = losses[..10].iter().sum::<f32>() / 10.0;
    let tail: f32 = losses[50..].iter().sum::<f32>() / 10.0;
    assert!(
        tail < head * 0.85,
        "loss did not fall: head {head} tail {tail} ({losses:?})"
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn adam_state_actually_updates_params() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.bert.clone();
    let (train, _) = emotion::load_small(3, 64, 8);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let mut batcher = TextBatcher::new(&train, &tok, 32);
    let mut rng = Rng::new(3);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let before = store.get("encoder.0.ffn.in.weight").unwrap().clone();
    let mut trainer = Trainer::new(&rt, "bert_train_step_b32", store).unwrap();
    let b = batcher.next_batch();
    trainer.step_batch(&b.ids, &b.mask, &b.labels, 1e-3).unwrap();
    let after = trainer.store.get("encoder.0.ffn.in.weight").unwrap();
    assert!(before.max_abs_diff(after) > 0.0, "params unchanged after a step");
    assert_eq!(trainer.step, 1);
}

#[test]
fn cnn_training_reduces_loss() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let ccfg = rt.manifest.cnn.clone();
    let (train, _) = splitquant::data::images::load(1, 256, 8);
    let mut rng = Rng::new(1);
    let store = ParamStore::init_cnn(&ccfg.param_order(), &mut rng);
    let mut trainer = Trainer::new(&rt, "cnn_train_step_b32", store).unwrap();
    let mut losses = Vec::new();
    let mut cursor = 0;
    for _ in 0..25 {
        let (imgs, labels) = train.batch(cursor, 32);
        cursor += 32;
        losses.push(trainer.step_images(&imgs, &labels, 5e-3).unwrap());
    }
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[20..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "cnn loss did not fall: {losses:?}");
    // BN running stats must have moved off their init
    let mean = trainer.store.get("bn1.mean").unwrap();
    assert!(mean.data().iter().any(|&v| v != 0.0), "BN stats frozen");
}

#[test]
fn training_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.bert.clone();
    let run = || {
        let (train, _) = emotion::load_small(5, 64, 8);
        let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
        let mut batcher = TextBatcher::new(&train, &tok, 32);
        let mut rng = Rng::new(5);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let mut trainer = Trainer::new(&rt, "bert_train_step_b32", store).unwrap();
        trainer
            .train_text(&mut batcher, 5, &LrSchedule::Constant(1e-3), &mut rng, 0, |_| {})
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must give identical loss trajectories");
}
