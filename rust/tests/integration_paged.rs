//! End-to-end shard paging (the ISSUE-3 acceptance criteria): a quantized
//! model served under a residency budget ≤ 50 % of its packed payload
//! produces logits **byte-identical** to the fully-resident path, with
//! nonzero shard faults/evictions and resident bytes never exceeding the
//! budget — including through the full coordinator (batcher + workers) and
//! across `Arc`-shared replicas.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use splitquant::coordinator::{BatchExecutor, QuantExecutor, ServeConfig, Server};
use splitquant::data::HashTokenizer;
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::model::QuantizedBert;
use splitquant::quant::PackedModel;
use splitquant::shardstore::{PagedConfig, PagedModel};
use splitquant::splitquant::{
    default_quantizable, quantize_store, QuantizedModel, SplitQuantConfig,
};
use splitquant::tensor::{IntTensor, Tensor};
use splitquant::util::rng::Rng;

fn build(tag: &str) -> (BertConfig, ParamStore, QuantizedModel, PackedModel, PathBuf) {
    let cfg = BertConfig {
        vocab_size: 512,
        hidden: 16,
        layers: 2,
        heads: 2,
        ffn: 32,
        max_len: 16,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let mut rng = Rng::new(3);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = default_quantizable(&store);
    let (_, qm) = quantize_store(&store, &quantizable, &SplitQuantConfig::new(2)).unwrap();
    let pm = PackedModel::assemble(&store, &qm);
    let path = std::env::temp_dir().join(format!("sq_e2e_paged_{tag}.sqsh"));
    pm.save_sharded(&path).unwrap();
    (cfg, store, qm, pm, path)
}

/// A budget that forces paging (< pagable bytes) while staying within the
/// acceptance bound (≤ 50 % of the packed payload) and workable
/// (≥ the largest single shard).
fn half_pagable_budget(pm: &PackedModel, path: &PathBuf) -> usize {
    let probe = PagedModel::open(path, PagedConfig::default()).unwrap();
    let budget = probe.pagable_bytes() / 2;
    assert!(
        budget * 2 <= pm.payload_bytes(),
        "budget {budget} above 50% of payload {}",
        pm.payload_bytes()
    );
    assert!(budget >= probe.max_shard_bytes(), "budget below the largest shard");
    budget
}

#[test]
fn half_budget_forward_is_byte_identical_and_bounded() {
    let (cfg, store, qm, pm, path) = build("fwd");
    let budget = half_pagable_budget(&pm, &path);

    let resident = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
    let paged = PagedModel::open(
        &path,
        PagedConfig { residency_budget_bytes: budget, prefetch_depth: 1, ..PagedConfig::default() },
    )
    .unwrap();
    let paged_bert = QuantizedBert::from_paged(cfg.clone(), paged.clone()).unwrap();
    std::fs::remove_file(&path).ok();

    let mut rng = Rng::new(17);
    for round in 0..4 {
        let b = 1 + round % 3;
        let ids: Vec<i32> =
            (0..b * cfg.max_len).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let ids = IntTensor::new(&[b, cfg.max_len], ids).unwrap();
        let mask = Tensor::full(&[b, cfg.max_len], 1.0);
        let a = resident.forward(&ids, &mask).unwrap();
        let p = paged_bert.forward(&ids, &mask).unwrap();
        assert_eq!(a.shape(), p.shape());
        for (x, y) in a.data().iter().zip(p.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "round {round}: logits diverged");
        }
        let c = paged.counters();
        assert!(c.resident_bytes <= budget, "round {round}: over budget");
        assert!(c.peak_resident_bytes <= budget, "round {round}: peak over budget");
    }
    let c = paged.counters();
    assert!(c.shard_faults > 0, "no faults under a half budget");
    assert!(c.shard_evictions > 0, "no evictions under a half budget");
    assert!(c.bytes_paged_in > 0);
}

#[test]
fn served_through_the_coordinator_with_paging_metrics() {
    let (cfg, store, qm, pm, path) = build("serve");
    let budget = half_pagable_budget(&pm, &path);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);

    let serve_cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        workers: 2,
        queue_cap: 256,
        residency_budget_bytes: Some(budget),
        ..ServeConfig::default()
    };
    let resident_ex: Arc<dyn BatchExecutor> = Arc::new(
        QuantExecutor::resident(cfg.clone(), &store, &qm, vec![1, 4, 8]).unwrap(),
    );
    let paged_ex =
        Arc::new(QuantExecutor::paged(cfg.clone(), &path, vec![1, 4, 8], &serve_cfg).unwrap());
    let paged_handle = paged_ex.model().paged().unwrap().clone();
    std::fs::remove_file(&path).ok();

    let texts: Vec<String> = (0..40).map(|i| format!("paged request number {i}")).collect();
    let want: Vec<i32> = {
        let server = Server::start(resident_ex, tok.clone(), serve_cfg.clone());
        let labels =
            texts.iter().map(|t| server.classify(t).unwrap().label).collect();
        let m = server.shutdown();
        assert_eq!(m.shard_faults, 0, "resident executor reported paging");
        labels
    };

    let server = Server::start(paged_ex, tok, serve_cfg);
    for (text, &label) in texts.iter().zip(&want) {
        assert_eq!(server.classify(text).unwrap().label, label, "{text}");
    }
    // counters reach the serving metrics while running and after shutdown
    let live = server.metrics();
    assert!(live.shard_faults > 0);
    let m = server.shutdown();
    assert_eq!(m.completed, texts.len());
    assert!(m.shard_faults > 0, "paged serving never faulted");
    assert!(m.shard_evictions > 0, "paged serving never evicted");
    assert!(m.bytes_paged_in > 0);
    let c = paged_handle.counters();
    assert!(
        c.peak_resident_bytes <= budget,
        "resident bytes {} exceeded the budget {budget}",
        c.peak_resident_bytes
    );
}

#[test]
fn replicas_share_one_residency_working_set() {
    // sharing semantics, not pressure: an ample budget shows that a second
    // replica runs entirely off the first replica's faults — N replicas
    // hold ~1× resident shard bytes (the paged analogue of
    // tests/integration_share.rs)
    let (cfg, _store, _qm, _pm, path) = build("replicas");
    let paged = PagedModel::open(&path, PagedConfig::default()).unwrap();
    let ex1 = QuantExecutor::from_paged(cfg.clone(), paged.clone(), vec![1]).unwrap();
    let ex2 = QuantExecutor::from_paged(cfg.clone(), paged.clone(), vec![1]).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(ex1.model().paged().unwrap().shares_residency(ex2.model().paged().unwrap()));
    // the pinned set is one allocation across replicas — including the
    // dequantized token embedding (cached per PagedModel, not per replica)
    for name in ["embeddings.token", "embeddings.position", "embeddings.ln.gamma"] {
        assert!(
            ex1.model().fp32_params().shares_tensor(ex2.model().fp32_params(), name),
            "{name} duplicated across replicas"
        );
    }

    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (ids, mask) = tok.encode("replica probe");
    let ids = IntTensor::new(&[1, cfg.max_len], ids).unwrap();
    let mask = Tensor::new(&[1, cfg.max_len], mask).unwrap();

    let l1 = ex1.classify(&ids, &mask, 1).unwrap();
    let cold = paged.counters().shard_faults;
    assert!(cold > 0);
    let l2 = ex2.classify(&ids, &mask, 1).unwrap();
    let c = paged.counters();
    assert_eq!(l1, l2, "replicas disagree");
    assert_eq!(c.shard_faults, cold, "replica re-faulted a shared-resident shard");
    // both replicas together hold exactly one copy of the pagable set
    assert!(c.resident_bytes <= paged.pagable_bytes());
    assert_eq!(c.shard_evictions, 0);
}
