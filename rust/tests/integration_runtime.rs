//! Integration: the PJRT runtime against the AOT artifacts — the L2 ⇄ L3
//! contract. Every test is skipped (with a notice) when `make artifacts` has
//! not been run.

use std::path::{Path, PathBuf};

use splitquant::data::{emotion, pad_to_batches, HashTokenizer};
use splitquant::model::params::ParamStore;
use splitquant::model::BertModel;
use splitquant::quant::{qrange, QParams};
use splitquant::runtime::literal::{i8_literal, Value};
use splitquant::runtime::Runtime;
use splitquant::tensor::Tensor;
use splitquant::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_abi_matches_rust_configs() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    rt.manifest.validate_abi().unwrap();
    assert!(rt.manifest.executables.len() >= 10);
}

#[test]
fn rust_executor_matches_pjrt_forward_across_batch_sizes() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.bert.clone();
    let mut rng = Rng::new(11);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let model = BertModel::new(cfg.clone(), store.clone()).unwrap();
    let (_, test) = emotion::load_small(11, 4, 64);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);

    for b in [1usize, 8, 32] {
        let (batches, _) = pad_to_batches(&test, &tok, b);
        let exe = rt.load(&format!("bert_fwd_b{b}")).unwrap();
        let batch = &batches[0];
        let rust = model.forward(&batch.ids, &batch.mask);
        let mut inputs: Vec<Value> =
            store.flat_tensors().map(|t| Value::F32(t.clone())).collect();
        inputs.push(Value::I32(batch.ids.clone()));
        inputs.push(Value::F32(batch.mask.clone()));
        let pjrt = exe.run_f32(&inputs).unwrap();
        let gap = rust.max_abs_diff(&pjrt);
        assert!(gap < 1e-4, "b{b}: executor gap {gap}");
    }
}

#[test]
fn fake_quant_executable_matches_rust_qparams() {
    // the standalone L1 Pallas kernel, AOT-compiled, vs quant::scheme
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("fake_quant_256x512").unwrap();
    let mut rng = Rng::new(3);
    let x = Tensor::randn(&[256, 512], 0.0, 2.0, &mut rng);
    for bits in [2u8, 4, 8] {
        let (lo, hi) = x.min_max();
        let p = QParams::from_range(lo, hi, bits);
        let (qmin, qmax) = qrange(bits);
        let one = |v: f32| Tensor::new(&[1, 1], vec![v]).unwrap();
        let out = exe
            .run_f32(&[
                Value::F32(x.clone()),
                Value::F32(one(p.scale)),
                Value::F32(one(p.zp)),
                Value::F32(one(qmin as f32)),
                Value::F32(one(qmax as f32)),
            ])
            .unwrap();
        let mut expect = x.clone();
        for v in expect.data_mut() {
            *v = p.fake(*v);
        }
        let gap = out.max_abs_diff(&expect);
        assert!(gap < 1e-5, "bits {bits}: kernel gap {gap}");
    }
}

#[test]
fn split_linear_executable_matches_rust_dequant_matmul() {
    // the deployment hot path: Pallas split_matmul kernel vs QTensor dequant
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    for (m, k, n) in [(32usize, 128usize, 128usize), (32, 128, 512)] {
        let exe = rt.load(&format!("split_linear_{m}x{k}x{n}")).unwrap();
        let mut rng = Rng::new((m + k + n) as u64);
        let x = Tensor::randn(&[m, k], 0.0, 1.0, &mut rng);
        // random split tensor at INT2 (codes int8, ids 0..3)
        let (qmin, qmax) = qrange(2);
        let codes: Vec<i8> =
            (0..k * n).map(|_| (qmin + rng.below(4) as i32) as i8).collect();
        let cid: Vec<i8> = (0..k * n).map(|_| rng.below(3) as i8).collect();
        let params: Vec<QParams> = (0..3)
            .map(|i| QParams {
                scale: 0.5 + i as f32,
                zp: (qmin + i as i32) as f32,
                bits: 2,
            })
            .collect();
        let scales = Tensor::new(&[1, 3], params.iter().map(|p| p.scale).collect()).unwrap();
        let zps = Tensor::new(&[1, 3], params.iter().map(|p| p.zp).collect()).unwrap();

        let spec = &exe.spec;
        let lits = vec![
            splitquant::runtime::literal::to_literal(&Value::F32(x.clone()), &spec.inputs[0])
                .unwrap(),
            i8_literal(&codes, &[k, n], &spec.inputs[1]).unwrap(),
            i8_literal(&cid, &[k, n], &spec.inputs[2]).unwrap(),
            splitquant::runtime::literal::to_literal(&Value::F32(scales), &spec.inputs[3])
                .unwrap(),
            splitquant::runtime::literal::to_literal(&Value::F32(zps), &spec.inputs[4])
                .unwrap(),
        ];
        let out = exe.run_literals(&lits).unwrap().remove(0).into_f32().unwrap();

        // rust reference: dequant elementwise then matmul
        let w: Vec<f32> = codes
            .iter()
            .zip(&cid)
            .map(|(&q, &c)| params[c as usize].dequantize(q))
            .collect();
        let w = Tensor::new(&[k, n], w).unwrap();
        let expect = splitquant::tensor::ops::matmul(&x, &w);
        let gap = out.max_abs_diff(&expect);
        assert!(gap < 2e-3, "{m}x{k}x{n}: split kernel gap {gap}");
        assert_eq!((qmax) as i32, 1); // silence unused warning paranoia
    }
}

#[test]
fn cluster_assign_executable_matches_rust_kmeans_assign() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("cluster_assign_128x128").unwrap();
    let mut rng = Rng::new(9);
    let x = Tensor::randn(&[128, 128], 0.0, 3.0, &mut rng);
    let cents = Tensor::new(&[1, 3], vec![-2.0, 0.1, 2.5]).unwrap();
    let mut out = exe
        .run(&[Value::F32(x.clone()), Value::F32(cents.clone())])
        .unwrap();
    let ids = out.remove(0).into_i32().unwrap();
    let expect = splitquant::clustering::kmeans::assign(x.data(), &[-2.0, 0.1, 2.5]);
    for (a, &b) in expect.iter().zip(ids.data()) {
        assert_eq!(*a as i32, b);
    }
}

#[test]
fn actquant_executable_matches_rust_act_hook() {
    // equal per-chunk triples == per-tensor; and the AOT act-quant graph
    // (L1 pallas fake_quant inside L2) must match the Rust hook twin
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.bert.clone();
    let mut rng = Rng::new(21);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let model = BertModel::new(cfg.clone(), store.clone()).unwrap();
    let (_, test) = emotion::load_small(21, 4, 32);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, n) = pad_to_batches(&test, &tok, 32);

    // calibrate on the same batch with the rust hook
    let mut cal = splitquant::splitquant::ActCalibrator::new(&cfg);
    {
        let mut hook = cal.hook();
        model.forward_hooked(&batches[0].ids, &batches[0].mask, Some(&mut hook));
    }
    let bits = 4;
    let act = cal.to_params(bits, splitquant::splitquant::ActQuantMode::Split);

    // rust path
    let rust_acc =
        splitquant::eval::accuracy_rust(&cfg, &store, &batches, n, Some(&act)).unwrap();
    // pjrt path through the actquant executable
    let pjrt_acc =
        splitquant::eval::accuracy_pjrt_actquant(&rt, &store, &batches, n, &act).unwrap();
    assert!(
        (rust_acc - pjrt_acc).abs() < 0.101,
        "act-quant accuracy gap: rust {rust_acc} vs pjrt {pjrt_acc}"
    );

    // logit-level agreement on one batch
    let mut hook = act.hook(&cfg);
    let rust_logits =
        model.forward_hooked(&batches[0].ids, &batches[0].mask, Some(&mut hook));
    let exe = rt.load("bert_fwd_actquant_b32").unwrap();
    let (scales, zps) = act.to_arrays();
    let (qmin, qmax) = qrange(bits);
    let mut inputs: Vec<Value> = store.flat_tensors().map(|t| Value::F32(t.clone())).collect();
    inputs.push(Value::I32(batches[0].ids.clone()));
    inputs.push(Value::F32(batches[0].mask.clone()));
    inputs.push(Value::F32(scales));
    inputs.push(Value::F32(zps));
    inputs.push(Value::F32(Tensor::scalar(qmin as f32)));
    inputs.push(Value::F32(Tensor::scalar(qmax as f32)));
    let pjrt_logits = exe.run_f32(&inputs).unwrap();
    let gap = rust_logits.max_abs_diff(&pjrt_logits);
    assert!(gap < 2e-2, "actquant logits gap {gap}");
}

#[test]
fn compile_cache_reuses_executables() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let a = rt.load("bert_fwd_b1").unwrap();
    let b = rt.load("bert_fwd_b1").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert_eq!(rt.compiled_count(), 1);
}
