//! Sharing semantics of the Arc-backed [`ParamStore`]: O(1) replica views
//! via `share()`, pointer-equality of tensors across replicas, copy-on-write
//! isolation after `set`/`get_mut`, and ~1× resident weight bytes for N
//! serving replicas (the ISSUE-2 acceptance criteria).

use std::sync::Arc;

use splitquant::coordinator::{BatchExecutor, RustExecutor};
use splitquant::data::HashTokenizer;
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::quant::pipeline::{QuantPipeline, SplitQuantPass};
use splitquant::tensor::{IntTensor, Tensor};
use splitquant::util::proptest::check;
use splitquant::util::rng::Rng;

fn tiny_store() -> (BertConfig, ParamStore) {
    let cfg = BertConfig {
        vocab_size: 512,
        hidden: 16,
        layers: 1,
        heads: 2,
        ffn: 32,
        max_len: 16,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let mut rng = Rng::new(0);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    (cfg, store)
}

#[test]
fn share_is_pointer_equal_everywhere() {
    let (_, store) = tiny_store();
    let replicas: Vec<ParamStore> = (0..4).map(|_| store.share()).collect();
    for r in &replicas {
        for name in store.names() {
            assert!(
                Arc::ptr_eq(&store.handle(name).unwrap(), &r.handle(name).unwrap()),
                "{name} not shared"
            );
            assert!(r.shares_tensor(&store, name), "{name}");
        }
    }
}

#[test]
fn copy_on_write_isolates_replicas() {
    let (_, store) = tiny_store();
    let mut replica = store.share();
    let name = "encoder.0.attn.q.weight";
    let shape = store.get(name).unwrap().shape().to_vec();
    replica.set(name, Tensor::ones(&shape)).unwrap();
    // the replica diverged on the touched tensor only
    assert!(!replica.shares_tensor(&store, name));
    assert!(replica.get(name).unwrap().data().iter().all(|&v| v == 1.0));
    // the original is untouched (randn init, not all-ones)
    assert!(store.get(name).unwrap().data().iter().any(|&v| v != 1.0));
    // every other tensor is still the same allocation
    for n in store.names().iter().filter(|n| n.as_str() != name) {
        assert!(replica.shares_tensor(&store, n), "{n}");
    }
}

#[test]
fn get_mut_copy_on_writes_the_touched_tensor() {
    let (_, store) = tiny_store();
    let mut replica = store.share();
    let name = "pooler.bias";
    replica.get_mut(name).unwrap().data_mut()[0] = 42.0;
    assert!(!replica.shares_tensor(&store, name));
    assert_eq!(store.get(name).unwrap().data()[0], 0.0);
    assert_eq!(replica.get(name).unwrap().data()[0], 42.0);
}

#[test]
fn n_replicas_hold_one_copy_of_the_weights() {
    let (_, store) = tiny_store();
    let one = store.byte_size();
    let replicas: Vec<ParamStore> = (0..8).map(|_| store.share()).collect();
    let mut stores: Vec<&ParamStore> = vec![&store];
    stores.extend(replicas.iter());
    // 9 views, exactly 1× resident weight bytes
    assert_eq!(ParamStore::resident_bytes(stores), one);

    // one COW write grows the footprint by exactly the touched tensor
    let mut hot = store.share();
    let name = "classifier.weight";
    let zeroed = Tensor::zeros(store.get(name).unwrap().shape());
    hot.set(name, zeroed).unwrap();
    assert_eq!(
        ParamStore::resident_bytes([&store, &hot]),
        one + store.get(name).unwrap().byte_size()
    );
}

#[test]
fn serving_replicas_share_weights_end_to_end() {
    let (cfg, store) = tiny_store();
    // two serving executors built from O(1) shares of one store
    let ex1 = RustExecutor::new(cfg.clone(), store.share(), vec![1, 4]).unwrap();
    let ex2 = RustExecutor::new(cfg.clone(), store.share(), vec![1, 4]).unwrap();
    for name in store.names() {
        assert!(ex1.params().shares_tensor(ex2.params(), name), "{name}");
        assert!(ex1.params().shares_tensor(&store, name), "{name}");
    }
    assert_eq!(
        ParamStore::resident_bytes([&store, ex1.params(), ex2.params()]),
        store.byte_size()
    );
    // both replicas serve and agree
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (ids, mask) = tok.encode("replica agreement probe");
    let ids = IntTensor::new(&[1, cfg.max_len], ids).unwrap();
    let mask = Tensor::new(&[1, cfg.max_len], mask).unwrap();
    assert_eq!(
        ex1.classify(&ids, &mask, 1).unwrap(),
        ex2.classify(&ids, &mask, 1).unwrap()
    );
}

#[test]
fn quantization_pipeline_shares_untouched_tensors() {
    let (_, store) = tiny_store();
    let artifact = QuantPipeline::new()
        .pass(SplitQuantPass::bits(4))
        .run(&store)
        .unwrap();
    // non-quantizable parameters were never copied
    assert!(artifact.eval.shares_tensor(&store, "embeddings.ln.gamma"));
    assert!(artifact.eval.shares_tensor(&store, "embeddings.position"));
    // quantized weights were copy-on-written, source intact
    assert!(!artifact.eval.shares_tensor(&store, "encoder.0.attn.q.weight"));
    let quantized = artifact.tensors.len();
    assert!(quantized > 0);
    // resident bytes: 1× the store + only the rewritten tensors
    let rewritten: usize = store
        .names()
        .iter()
        .filter(|n| !artifact.eval.shares_tensor(&store, n.as_str()))
        .map(|n| store.get(n).unwrap().byte_size())
        .sum();
    assert_eq!(
        ParamStore::resident_bytes([&store, &artifact.eval]),
        store.byte_size() + rewritten
    );
}

#[test]
fn property_cow_never_leaks_into_the_base() {
    check("cow isolation", 25, |rng| {
        let rows = rng.range(1, 8);
        let cols = rng.range(1, 8);
        let blen = rng.range(1, 8);
        let order = vec![
            ("a.weight".to_string(), vec![rows, cols]),
            ("a.bias".to_string(), vec![blen]),
        ];
        let base = ParamStore::zeros(&order);
        let mut replica = base.share();
        let name = if rng.below(2) == 0 { "a.weight" } else { "a.bias" };
        let shape = base.get(name).unwrap().shape().to_vec();
        replica.set(name, Tensor::randn(&shape, 0.0, 1.0, rng)).unwrap();
        assert!(!replica.shares_tensor(&base, name));
        let other = if name == "a.weight" { "a.bias" } else { "a.weight" };
        assert!(replica.shares_tensor(&base, other));
        // the base never sees the replica's write
        assert!(base.get(name).unwrap().data().iter().all(|&v| v == 0.0));
    });
}
