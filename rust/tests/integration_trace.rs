//! End-to-end tracing & telemetry (the ISSUE-8 acceptance criteria):
//! spans balance under pooled dispatch, disabled tracing is inert (no
//! registration, no counters, near-zero cost), ring overflow drops oldest
//! with honest accounting, and one traced paged serving run produces a
//! Perfetto-loadable Chrome trace with request-lifecycle spans, shard
//! fault events and kernel chunk spans, plus latency-breakdown rows that
//! merge idempotently into a BENCH_serving-style JSON file.
//!
//! The trace enable flag, counter table and thread-ring registry are
//! process-wide, so every test here serializes on one mutex and leaves
//! tracing disabled (default ring capacity restored) on exit.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use splitquant::coordinator::{QuantExecutor, ServeConfig, Server};
use splitquant::data::HashTokenizer;
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::parallel::{kernels, ParallelConfig};
use splitquant::quant::PackedModel;
use splitquant::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
use splitquant::tensor::Tensor;
use splitquant::trace::{self, Category, EventKind};
use splitquant::util::json::Json;
use splitquant::util::rng::Rng;

/// Serializes every test that flips the process-wide trace state.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

/// One shared worker-pool config: `configure` is first-caller-wins
/// process-wide, so every test (and every `Server::start` below) installs
/// the same values — tiny `serial_flops` forces pooled kernel dispatch
/// even for this file's deliberately small models.
fn pool_cfg() -> ParallelConfig {
    ParallelConfig { threads: 2, serial_flops: 1, ..ParallelConfig::default() }
}

/// Take the lock, install the pool config and drain stale events left by
/// other tests' threads, so each test asserts only on its own events.
fn trace_test_setup() -> std::sync::MutexGuard<'static, ()> {
    let guard = TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    splitquant::parallel::configure(pool_cfg());
    trace::set_enabled(false);
    let _ = trace::snapshot();
    guard
}

fn all_events(snap: &trace::Snapshot) -> impl Iterator<Item = &trace::Event> {
    snap.threads.iter().flat_map(|(_, evs)| evs.iter())
}

// ------------------------------------------------------- span balance --

#[test]
fn spans_balance_under_pooled_dispatch() {
    let _g = trace_test_setup();
    trace::set_enabled(true);

    // unconditionally pooled matmul: every worker task opens one RAII
    // chunk span; 64 rows / (2 threads × 4 oversplit) = several chunks
    let a = Tensor::full(&[64, 48], 0.5);
    let b = Tensor::full(&[48, 32], -0.25);
    let c = kernels::matmul(&a, &b);
    assert_eq!(c.shape(), &[64usize, 32][..]);

    trace::set_enabled(false);
    let snap = trace::snapshot();
    let mut chunk_spans = 0usize;
    for (name, evs) in &snap.threads {
        let enters = evs.iter().filter(|e| e.kind == EventKind::Enter).count();
        let exits = evs.iter().filter(|e| e.kind == EventKind::Exit).count();
        assert_eq!(enters, exits, "unbalanced spans on thread {name:?}: {evs:?}");
        chunk_spans += evs
            .iter()
            .filter(|e| e.kind == EventKind::Enter && e.name == "matmul-chunk")
            .count();
    }
    assert!(chunk_spans >= 2, "pooled matmul produced {chunk_spans} chunk spans");
    assert!(
        all_events(&snap).all(|e| e.name != "matmul-chunk" || e.cat == Category::Kernel),
        "chunk spans must use the Kernel category"
    );
}

// --------------------------------------------------- disabled is inert --

#[test]
fn disabled_tracing_registers_nothing_and_costs_little() {
    let _g = trace_test_setup();
    assert!(!trace::enabled());

    // a thread that only ever emits while disabled must never register a
    // ring (the disabled path may not touch the thread-local recorder)
    std::thread::Builder::new()
        .name("inert-probe".to_string())
        .spawn(|| {
            for i in 0..1000u64 {
                let _sp = trace::span(Category::Batch, "inert-span");
                trace::instant(Category::Shard, "inert-instant", i, 0);
                trace::count("inert_counter", 1);
            }
        })
        .unwrap()
        .join()
        .unwrap();

    let snap = trace::snapshot();
    assert!(
        snap.threads.iter().all(|(name, _)| name != "inert-probe"),
        "disabled emission registered a ring: {:?}",
        snap.threads.iter().map(|(n, _)| n).collect::<Vec<_>>()
    );
    assert!(
        !trace::counters().contains_key("inert_counter"),
        "disabled count() reached the counter table"
    );

    // near-zero overhead: 1M disabled span creations are one relaxed load
    // each — generous bound so debug builds on loaded CI pass comfortably
    let t0 = Instant::now();
    for _ in 0..1_000_000 {
        let _sp = trace::span(Category::Kernel, "disabled-probe");
    }
    let dt = t0.elapsed();
    assert!(dt < Duration::from_secs(2), "1M disabled spans took {dt:?}");
}

// ---------------------------------------------------- overflow bounds --

#[test]
fn ring_overflow_drops_oldest_and_counts_drops() {
    let _g = trace_test_setup();
    trace::set_enabled(true);
    trace::set_ring_capacity(64);

    // the probe thread's ring is created on its first emission, at the
    // reduced capacity; 200 pushes must keep only the newest 64
    std::thread::Builder::new()
        .name("overflow-probe".to_string())
        .spawn(|| {
            for i in 0..200u64 {
                trace::instant(Category::Shard, "overflow-ev", i, 0);
            }
        })
        .unwrap()
        .join()
        .unwrap();
    trace::set_ring_capacity(splitquant::trace::ring::DEFAULT_CAPACITY);
    trace::set_enabled(false);

    let snap = trace::snapshot();
    let kept: Vec<u64> = snap
        .threads
        .iter()
        .find(|(name, _)| name == "overflow-probe")
        .map(|(_, evs)| evs.iter().map(|e| e.a).collect())
        .expect("probe thread registered a ring");
    assert!(!kept.is_empty() && kept.len() <= 64, "kept {} events", kept.len());
    // drop-oldest: the survivors are the newest events, oldest-first
    assert_eq!(*kept.last().unwrap(), 199, "newest event lost: {kept:?}");
    assert!(kept[0] >= 136, "oldest events survived overflow: {kept:?}");
    assert!(kept.windows(2).all(|w| w[0] < w[1]), "drain out of order: {kept:?}");
    assert!(snap.dropped >= 136, "only {} drops accounted", snap.dropped);
    assert!(trace::dropped_total() >= snap.dropped);
}

// ------------------------------------------- traced paged serving run --

fn build_paged(tag: &str) -> (BertConfig, PathBuf, usize) {
    let cfg = BertConfig {
        vocab_size: 512,
        hidden: 16,
        layers: 2,
        heads: 2,
        ffn: 32,
        max_len: 16,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let mut rng = Rng::new(3);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = default_quantizable(&store);
    let (_, qm) = quantize_store(&store, &quantizable, &SplitQuantConfig::new(2)).unwrap();
    let pm = PackedModel::assemble(&store, &qm);
    let path = std::env::temp_dir().join(format!("sq_trace_it_{tag}.sqsh"));
    pm.save_sharded(&path).unwrap();
    let budget = {
        use splitquant::shardstore::{PagedConfig, PagedModel};
        PagedModel::open(&path, PagedConfig::default()).unwrap().pagable_bytes() / 2
    };
    (cfg, path, budget)
}

#[test]
fn traced_paged_serving_exports_chrome_trace_and_breakdown() {
    let _g = trace_test_setup();
    trace::set_enabled(true);

    let (cfg, path, budget) = build_paged("serve");
    let serve_cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        workers: 2,
        queue_cap: 256,
        parallel: pool_cfg(),
        residency_budget_bytes: Some(budget),
        ..ServeConfig::default()
    };
    let exec =
        Arc::new(QuantExecutor::paged(cfg.clone(), &path, vec![1, 4], &serve_cfg).unwrap());
    std::fs::remove_file(&path).ok();
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let server = Server::start(exec, tok, serve_cfg);

    let requests = 24usize;
    let mut done = 0usize;
    while done < requests {
        let window = 8.min(requests - done);
        let rxs: Vec<_> = (0..window)
            .map(|k| server.submit(&format!("traced request number {}", done + k)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(60))
                .expect("request timed out")
                .expect("request degraded");
            done += 1;
        }
    }

    // Prometheus-style exposition is live while the server runs
    let text = server.telemetry_text();
    assert!(text.contains("splitquant_requests_completed_total"), "{text}");
    assert!(text.contains("splitquant_shard_faults_total"), "{text}");
    assert!(text.contains("splitquant_request_stage_us"), "{text}");

    let m = server.shutdown();
    trace::set_enabled(false);
    assert_eq!(m.completed, requests);
    assert!(m.shard_faults > 0, "half budget never faulted");

    // -- the trace carries the full event taxonomy of the serving path
    let snap = trace::snapshot();
    let has = |pred: &dyn Fn(&trace::Event) -> bool| all_events(&snap).any(|e| pred(e));
    assert!(
        has(&|e| e.kind == EventKind::Complete && e.name == "req-total"),
        "no request-lifecycle slices in the trace"
    );
    assert!(
        has(&|e| e.kind == EventKind::Instant
            && e.cat == Category::Shard
            && e.name == "shard-fault"
            && e.a > 0),
        "no shard-fault events (with byte counts) in the trace"
    );
    assert!(
        has(&|e| e.kind == EventKind::Enter && e.cat == Category::Kernel),
        "no kernel chunk spans despite serial_flops=1"
    );

    // -- Chrome export: Perfetto-loadable JSON, byte-deterministic
    let json = trace::chrome::chrome_trace_string(&snap);
    assert_eq!(json, trace::chrome::chrome_trace_string(&snap), "export not deterministic");
    let parsed = Json::parse(&json).expect("chrome trace must be valid JSON");
    let evs = parsed.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(evs.len() > requests, "only {} trace events", evs.len());
    assert!(json.contains("\"name\":\"req-total\""), "lifecycle rows missing from export");
    let out = std::env::temp_dir().join("sq_trace_it_serve.trace.json");
    trace::chrome::write_chrome_trace(&out, &snap).unwrap();
    assert_eq!(std::fs::read_to_string(&out).unwrap(), json, "file diverges from string");
    std::fs::remove_file(&out).ok();

    // -- latency-breakdown rows merge idempotently into the bench JSON
    let rows = m.breakdown_records("paged-it", "simd");
    assert!(
        rows.iter().any(|r| r.bench == "breakdown-total"),
        "no breakdown-total row: {rows:?}"
    );
    let bench_path = std::env::temp_dir().join("sq_trace_it_bench.json");
    std::fs::remove_file(&bench_path).ok();
    splitquant::report::bench_json::merge_write(&bench_path, &rows).unwrap();
    let once = std::fs::read_to_string(&bench_path).unwrap();
    splitquant::report::bench_json::merge_write(&bench_path, &rows).unwrap();
    let twice = std::fs::read_to_string(&bench_path).unwrap();
    assert_eq!(once, twice, "re-merging identical rows changed the file");
    assert!(once.contains("breakdown-queue"), "{once}");
    std::fs::remove_file(&bench_path).ok();
}

// ------------------------------- panic containment → unfinished span --

/// A batch that panics mid-span (its RAII guard lost to the unwind, so no
/// Exit event reaches the ring) must still export cleanly: the server
/// contains the panic at its `catch_unwind` batch boundary, and the Chrome
/// exporter renders the dangling Enter as a complete slice running to the
/// end of the snapshot, flagged `"unfinished": true` — visible evidence of
/// where the crash interrupted the timeline instead of a corrupt or
/// unbalanced export.
#[test]
fn panicking_batch_exports_unfinished_span() {
    let _g = trace_test_setup();
    trace::set_enabled(true);

    struct PanicExecutor;
    impl splitquant::coordinator::BatchExecutor for PanicExecutor {
        fn classify(
            &self,
            _ids: &splitquant::tensor::IntTensor,
            _mask: &Tensor,
            _batch_size: usize,
        ) -> splitquant::Result<Vec<i32>> {
            // forget the guard so the unwind cannot record the Exit — the
            // shape of a real crash, where the span never closes
            std::mem::forget(trace::span(Category::Batch, "doomed-batch"));
            panic!("injected batch panic");
        }
        fn batch_sizes(&self) -> Vec<usize> {
            vec![1]
        }
    }

    let tok = HashTokenizer::new(512, 16);
    let server = Server::start(
        Arc::new(PanicExecutor),
        tok,
        ServeConfig {
            max_wait: Duration::from_millis(1),
            workers: 1,
            queue_cap: 16,
            parallel: pool_cfg(),
            ..ServeConfig::default()
        },
    );
    let rx = server.submit("this batch will panic").unwrap();
    // a contained panic answers with a clean error (or at worst drops the
    // responder) — it must never answer with a classification
    let resp = rx.recv_timeout(Duration::from_secs(30));
    assert!(!matches!(resp, Ok(Ok(_))), "panicking executor cannot classify");
    let m = server.shutdown();
    trace::set_enabled(false);
    assert!(m.exec_panics >= 1, "panic was not contained/counted");

    let snap = trace::snapshot();
    assert!(
        all_events(&snap).any(|e| e.kind == EventKind::Enter && e.name == "doomed-batch"),
        "the doomed span's Enter never reached the ring"
    );
    let json = trace::chrome::chrome_trace_string(&snap);
    let parsed = Json::parse(&json).expect("chrome trace must stay valid JSON after a panic");
    assert!(parsed.get("traceEvents").and_then(Json::as_arr).is_ok(), "{json}");
    assert!(json.contains("\"name\":\"doomed-batch\""), "{json}");
    assert!(json.contains("\"unfinished\":true"), "{json}");
}
