//! Chaos serving (the fault-tolerance acceptance criteria): a paged model
//! served through the full coordinator while a seeded
//! [`splitquant::shardstore::FaultyIo`] injects IO errors, short reads and
//! byte corruption on the shard path. The contract under injection:
//!
//! * requests that complete return labels **byte-identical** to a
//!   fault-free run — corrupted reads are caught by the CRC layer and
//!   retried, never served;
//! * requests that cannot complete (a shard exhausted its retry budget and
//!   was quarantined) get an error response — they never hang and never
//!   kill the process;
//! * the residency budget holds throughout;
//! * the serving counters reconcile exactly with the injector's ground
//!   truth, and the whole schedule replays identically across runs.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use splitquant::coordinator::{Metrics, QuantExecutor, ServeConfig, Server};
use splitquant::data::HashTokenizer;
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::quant::PackedModel;
use splitquant::shardstore::{FaultConfig, PagedConfig, PagedModel, RetryPolicy};
use splitquant::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
use splitquant::util::rng::Rng;

fn build(tag: &str) -> (BertConfig, PackedModel, PathBuf) {
    let cfg = BertConfig {
        vocab_size: 512,
        hidden: 16,
        layers: 2,
        heads: 2,
        ffn: 32,
        max_len: 16,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let mut rng = Rng::new(3);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = default_quantizable(&store);
    let (_, qm) = quantize_store(&store, &quantizable, &SplitQuantConfig::new(2)).unwrap();
    let pm = PackedModel::assemble(&store, &qm);
    let path = std::env::temp_dir().join(format!("sq_e2e_chaos_{tag}.sqsh"));
    pm.save_sharded(&path).unwrap();
    (cfg, pm, path)
}

/// A budget below the pagable set so shards keep cycling through disk (and
/// through the fault injector) for the whole run, not just during warm-up.
fn half_pagable_budget(path: &Path) -> usize {
    let probe = PagedModel::open(path, PagedConfig::default()).unwrap();
    let budget = probe.pagable_bytes() / 2;
    assert!(budget >= probe.max_shard_bytes(), "budget below the largest shard");
    budget
}

/// Injection ground truth snapshot: (io_errors, short_reads, corruptions).
type Injected = (u64, u64, u64);

/// Serve every text through its own blocking round-trip (single in-flight
/// request ⇒ the shard read sequence, and with it the fault schedule, is
/// identical run to run). Returns the per-request outcome (`Some(label)` on
/// success, `None` when the request was degraded to an error), the final
/// metrics, and the injector's counters when faults were configured.
fn serve_all(
    cfg: &BertConfig,
    path: &Path,
    serve_cfg: &ServeConfig,
    texts: &[String],
) -> (Vec<Option<i32>>, Metrics, Option<Injected>) {
    let ex =
        Arc::new(QuantExecutor::paged(cfg.clone(), path, vec![1, 4, 8], serve_cfg).unwrap());
    let paged = ex.model().paged().unwrap().clone();
    let stats = paged.fault_stats();
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let server = Server::start(ex, tok, serve_cfg.clone());
    let mut out = Vec::with_capacity(texts.len());
    for t in texts {
        let rx = server.submit(t).unwrap();
        // a degraded request must answer with Err — never hang
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("request hung");
        out.push(resp.ok().map(|r| r.label));
    }
    let m = server.shutdown();
    if let Some(budget) = serve_cfg.residency_budget_bytes {
        let c = paged.counters();
        assert!(
            c.peak_resident_bytes <= budget,
            "resident bytes {} exceeded the budget {budget}",
            c.peak_resident_bytes
        );
    }
    let injected = stats.map(|s| (s.io_errors(), s.short_reads(), s.corruptions()));
    (out, m, injected)
}

fn serve_cfg(budget: usize) -> ServeConfig {
    ServeConfig {
        max_wait: Duration::from_millis(1),
        workers: 1,
        queue_cap: 64,
        residency_budget_bytes: Some(budget),
        // zero backoff: the schedule (not wall clock) is what's under test
        retry: RetryPolicy {
            max_attempts: 4,
            base: Duration::ZERO,
            cap: Duration::ZERO,
        },
        ..ServeConfig::default()
    }
}

#[test]
fn survivors_are_byte_identical_and_counters_reconcile() {
    let (cfg, _pm, path) = build("main");
    let budget = half_pagable_budget(&path);
    let texts: Vec<String> = (0..30).map(|i| format!("chaos request number {i}")).collect();

    let base_cfg = serve_cfg(budget);
    let (baseline, base_m, base_stats) = serve_all(&cfg, &path, &base_cfg, &texts);
    assert!(baseline.iter().all(Option::is_some), "fault-free run degraded a request");
    assert_eq!(base_m.completed, texts.len());
    assert_eq!(base_m.integrity_failures, 0);
    assert_eq!(base_m.io_retries, 0);
    assert_eq!(base_m.shards_quarantined, 0);
    assert!(base_stats.is_none(), "fault-free run installed an injector");

    let mut total_injected = 0u64;
    for seed in [11u64, 77, 1234] {
        let mut faulty_cfg = serve_cfg(budget);
        faulty_cfg.fault = Some(FaultConfig::uniform(seed, 0.05));
        let (out, m, stats) = serve_all(&cfg, &path, &faulty_cfg, &texts);
        let (errors, shorts, corrupts) = stats.expect("injector installed");
        total_injected += errors + shorts + corrupts;

        // every survivor matches the fault-free label bit for bit
        for (i, o) in out.iter().enumerate() {
            if let Some(label) = o {
                assert_eq!(Some(*label), baseline[i], "seed {seed}: request {i} diverged");
            }
        }
        let degraded = out.iter().filter(|o| o.is_none()).count();
        assert_eq!(m.completed, texts.len() - degraded, "seed {seed}");
        if degraded > 0 {
            // the only way a request degrades here is a quarantined shard
            assert!(m.shards_quarantined > 0, "seed {seed}: errors without quarantine");
        }
        // counter algebra against the injection ground truth: every short
        // read / corruption fails CRC or parse exactly once, and every
        // injected failure is either retried or ends a shard's budget
        assert_eq!(
            m.integrity_failures as u64,
            shorts + corrupts,
            "seed {seed}: integrity failures don't match injected corruption"
        );
        assert_eq!(
            errors + shorts + corrupts,
            (m.io_retries + m.shards_quarantined) as u64,
            "seed {seed}: injected failures don't reconcile with retries + quarantines"
        );
    }
    assert!(total_injected > 0, "three seeds injected nothing — rates too low");
    std::fs::remove_file(&path).ok();
}

#[test]
fn retry_exhaustion_degrades_requests_not_the_process() {
    let (cfg, _pm, path) = build("exhaust");
    let texts: Vec<String> = (0..5).map(|i| format!("doomed request {i}")).collect();
    let mut sc = serve_cfg(half_pagable_budget(&path));
    sc.retry.max_attempts = 2;
    // an error rate this high exhausts a 2-attempt budget almost
    // immediately; the first pagable fetch quarantines and every request
    // needs that shard, so all of them must error — cleanly
    sc.fault = Some(FaultConfig { seed: 9, error_rate: 0.9, ..FaultConfig::default() });
    let ex = Arc::new(QuantExecutor::paged(cfg.clone(), &path, vec![1, 4, 8], &sc).unwrap());
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let server = Server::start(ex, tok, sc.clone());
    for t in &texts {
        let rx = server.submit(t).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("request hung");
        assert!(resp.is_err(), "{t}: served through a quarantined shard");
    }
    // the server is still alive and says so: readiness reports degradation
    let text = server.telemetry_text();
    assert!(text.contains("splitquant_up 1"), "{text}");
    assert!(text.contains("splitquant_degraded 1"), "{text}");
    let m = server.shutdown();
    assert_eq!(m.completed, 0);
    assert!(m.shards_quarantined >= 1, "no quarantine despite 90% error rate");
    assert_eq!(m.exec_panics, 0, "degradation must come from quarantine, not panics");
    std::fs::remove_file(&path).ok();
}

#[test]
fn fault_schedule_replays_identically() {
    let (cfg, _pm, path) = build("replay");
    let texts: Vec<String> = (0..20).map(|i| format!("replayed request {i}")).collect();
    let mut sc = serve_cfg(half_pagable_budget(&path));
    sc.fault = Some(FaultConfig::uniform(42, 0.05));

    let (out_a, m_a, stats_a) = serve_all(&cfg, &path, &sc, &texts);
    let (out_b, m_b, stats_b) = serve_all(&cfg, &path, &sc, &texts);
    assert_eq!(out_a, out_b, "per-request outcomes diverged across runs");
    assert_eq!(stats_a, stats_b, "injection counters diverged across runs");
    for (name, a, b) in [
        ("integrity_failures", m_a.integrity_failures, m_b.integrity_failures),
        ("io_retries", m_a.io_retries, m_b.io_retries),
        ("shards_quarantined", m_a.shards_quarantined, m_b.shards_quarantined),
        ("completed", m_a.completed, m_b.completed),
    ] {
        assert_eq!(a, b, "{name} diverged across runs");
    }
    std::fs::remove_file(&path).ok();
}
