//! End-to-end coverage for the mixed-precision autotuner (ISSUE 5): the
//! sensitivity sweep shares one FP32 store across candidates (`Arc::ptr_eq`
//! accounting), sweeps are deterministic, allocation respects the budget and
//! is monotone in it, and an [`AutoTunePass`]-quantized model round-trips
//! through the packed + sharded formats byte-identically with the realized
//! payload validated against the budget.

use std::sync::Arc;

use splitquant::autotune::{
    allocate, candidate_artifact, layer_groups, sweep, AutoTunePass, BitPlan, SweepConfig,
};
use splitquant::data::batch::TextBatch;
use splitquant::data::{emotion, pad_to_batches, HashTokenizer};
use splitquant::eval::agreement_rust;
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::quant::{PackedModel, QuantPipeline, SplitQuantPass};
use splitquant::splitquant::SplitQuantConfig;

fn tiny_setup() -> (BertConfig, ParamStore, Vec<TextBatch>, usize) {
    let cfg = BertConfig {
        vocab_size: 512,
        hidden: 16,
        layers: 1,
        heads: 2,
        ffn: 32,
        max_len: 16,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let mut rng = splitquant::util::rng::Rng::new(0);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let (_, test) = emotion::load_small(0, 10, 96);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, n) = pad_to_batches(&test, &tok, 16);
    (cfg, store, batches, n)
}

#[test]
fn sweep_time_requantization_shares_the_fp32_store() {
    // ISSUE-5 satellite: each candidate is an O(1) `share()` view — the
    // sweep must never deep-clone the FP32 store per (layer, bits) cell
    let (_, store, _, _) = tiny_setup();
    let groups = layer_groups(&store);
    let (_, params) = groups
        .iter()
        .find(|(l, _)| l == "encoder.0.attn.q")
        .expect("attn.q group exists");
    let base = SplitQuantConfig::new(2);
    let a2 = candidate_artifact(&store, params, 2, &base).unwrap();
    let a8 = candidate_artifact(&store, params, 8, &base).unwrap();

    for name in store.names() {
        if params.contains(name) {
            // the swept layer was copy-on-written
            assert!(!a2.eval.shares_tensor(&store, name), "{name} should have diverged");
        } else {
            // everything else is the same allocation, Arc::ptr_eq-level
            assert!(
                Arc::ptr_eq(&store.handle(name).unwrap(), &a2.eval.handle(name).unwrap()),
                "{name} was cloned by the sweep"
            );
            assert!(a8.eval.shares_tensor(&store, name), "{name} was cloned by the sweep");
        }
    }
    // N candidates cost 1x the store + only the swept layer's tensors each
    let touched: usize = params.iter().map(|n| store.get(n).unwrap().byte_size()).sum();
    assert_eq!(
        ParamStore::resident_bytes([&store, &a2.eval, &a8.eval]),
        store.byte_size() + 2 * touched
    );
}

#[test]
fn single_layer_sweeps_are_deterministic_across_runs() {
    let (cfg, store, batches, _) = tiny_setup();
    let calib = &batches[..2];
    let sweep_cfg = SweepConfig::default();
    let a = sweep(&cfg, &store, calib, &sweep_cfg).unwrap();
    let b = sweep(&cfg, &store, calib, &sweep_cfg).unwrap();
    assert_eq!(a.examples, b.examples);
    assert_eq!(a.layers.len(), b.layers.len());
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        assert_eq!(la.layer, lb.layer);
        assert_eq!(la.params, lb.params);
        for (oa, ob) in la.options.iter().zip(&lb.options) {
            assert_eq!(oa.bits, ob.bits);
            assert_eq!(oa.bytes, ob.bytes, "{}", la.layer);
            // bit-exact: the sweep is a pure function of (store, batches, cfg)
            assert_eq!(oa.kl.to_bits(), ob.kl.to_bits(), "{}", la.layer);
            assert_eq!(oa.max_abs_delta.to_bits(), ob.max_abs_delta.to_bits());
        }
    }
}

#[test]
fn int8_fidelity_column_fills_only_when_requested() {
    let (cfg, store, batches, _) = tiny_setup();
    let calib = &batches[..1];
    let off = sweep(&cfg, &store, calib, &SweepConfig::default()).unwrap();
    for l in &off.layers {
        for o in &l.options {
            assert!(o.kl_int8.is_none(), "{}: column filled without opting in", l.layer);
        }
    }
    let on_cfg = SweepConfig { int8_fidelity: true, ..SweepConfig::default() };
    let on = sweep(&cfg, &store, calib, &on_cfg).unwrap();
    for l in &on.layers {
        for o in &l.options {
            let kli = o.kl_int8.expect("int8 column requested");
            assert!(kli.is_finite() && kli >= 0.0, "{}: kl_int8 {kli}", l.layer);
        }
    }
    // the f32 columns are untouched by the extra measurement
    for (la, lb) in off.layers.iter().zip(&on.layers) {
        for (oa, ob) in la.options.iter().zip(&lb.options) {
            assert_eq!(oa.kl.to_bits(), ob.kl.to_bits(), "{}", la.layer);
            assert_eq!(oa.bytes, ob.bytes);
        }
    }
}

#[test]
fn allocation_respects_budget_and_is_monotone_on_real_sensitivities() {
    let (cfg, store, batches, _) = tiny_setup();
    let table = sweep(&cfg, &store, &batches[..2], &SweepConfig::default()).unwrap();
    let floor = table.uniform_bytes(2).unwrap();
    let ceil = table.uniform_bytes(8).unwrap();
    assert!(allocate(&table, floor - 1).is_err(), "sub-floor budget must error");

    let mut last_kl = f64::INFINITY;
    for step in 0..=4 {
        let budget = floor + (ceil - floor) * step / 4;
        let plan = allocate(&table, budget).unwrap();
        assert!(plan.planned_bytes <= budget, "{} > {budget}", plan.planned_bytes);
        assert!(plan.planned_kl <= last_kl + 1e-12, "KL rose with budget");
        last_kl = plan.planned_kl;
        // every quantizable layer group got an assignment
        assert_eq!(plan.layers.len(), table.layers.len());
    }
}

#[test]
fn autotuned_plan_end_to_end_beats_the_uniform_floor() {
    let (cfg, store, batches, n) = tiny_setup();
    let calib = &batches[..2];
    let sweep_cfg = SweepConfig::default();
    let table = sweep(&cfg, &store, calib, &sweep_cfg).unwrap();

    // the acceptance budget: uniform-INT4 packed size
    let budget = table.uniform_bytes(4).unwrap();
    let plan = allocate(&table, budget).unwrap();
    assert!(
        plan.layers.values().any(|&b| b > 2),
        "an INT4-sized budget must afford upgrades over the INT2 floor"
    );

    // expand the plan through the pipeline
    let artifact = QuantPipeline::new()
        .pass(AutoTunePass::new(plan.clone(), sweep_cfg.base))
        .run(&store)
        .unwrap();
    assert!(artifact.provenance[0].starts_with("autotune(budget="), "{:?}", artifact.provenance);
    let qm = artifact.quantized_model();
    let realized = qm.quantized_bytes();
    // byte cost is exact: planned == realized, and within budget
    assert_eq!(realized, plan.planned_bytes);
    assert!(realized <= budget);
    // per-layer widths landed as planned
    for (layer, params) in layer_groups(&store) {
        for p in &params {
            assert_eq!(qm.tensors[p].bits(), plan.layers[&layer], "{p}");
        }
    }

    // sharded artifact: realized payload validated against the budget
    let shards = std::env::temp_dir().join("sq_autotune_e2e.sqsh");
    let pm = PackedModel::assemble(&store, &qm);
    pm.save_sharded(&shards).unwrap();
    let validated = plan.validate_sharded(&shards).unwrap();
    assert_eq!(validated, realized);
    {
        let reader = splitquant::shardstore::ShardReader::open(&shards).unwrap();
        assert!(reader.quantized_payload_bytes() > 0);
    }
    std::fs::remove_file(&shards).ok();

    // a too-small budget on the same artifact fails validation
    let starved = BitPlan { budget_bytes: realized / 2, ..plan.clone() };
    let shards2 = std::env::temp_dir().join("sq_autotune_starved.sqsh");
    pm.save_sharded(&shards2).unwrap();
    assert!(starved.validate_sharded(&shards2).is_err());
    std::fs::remove_file(&shards2).ok();

    // fidelity: the plan (at <= INT4 bytes) must not lose to uniform INT2
    let int2 = QuantPipeline::new().pass(SplitQuantPass::bits(2)).run(&store).unwrap();
    let plan_agree = agreement_rust(&cfg, &store, &artifact.eval, &batches, n).unwrap();
    let int2_agree = agreement_rust(&cfg, &store, &int2.eval, &batches, n).unwrap();
    assert!(
        plan_agree >= int2_agree,
        "plan fidelity {plan_agree} below uniform INT2 {int2_agree}"
    );
}

#[test]
fn mixed_precision_packed_model_reloads_byte_identically() {
    // ISSUE-5 satellite: a BitPlan-quantized model must round-trip with its
    // per-layer bit-width metadata intact
    let (cfg, store, batches, _) = tiny_setup();
    let sweep_cfg = SweepConfig::default();
    let table = sweep(&cfg, &store, &batches[..1], &sweep_cfg).unwrap();
    let plan = allocate(&table, table.uniform_bytes(4).unwrap()).unwrap();
    let artifact = QuantPipeline::new()
        .pass(AutoTunePass::new(plan.clone(), sweep_cfg.base))
        .run(&store)
        .unwrap();
    let pm = PackedModel::assemble(&store, &artifact.quantized_model());

    let p1 = std::env::temp_dir().join("sq_autotune_rt_1.sqq");
    let p2 = std::env::temp_dir().join("sq_autotune_rt_2.sqq");
    pm.save(&p1).unwrap();
    let loaded = PackedModel::load(&p1).unwrap();
    loaded.save(&p2).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b2 = std::fs::read(&p2).unwrap();
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
    assert_eq!(b1, b2, "mixed-precision save->load->save is not byte-stable");
    for (layer, params) in layer_groups(&store) {
        for p in &params {
            assert_eq!(loaded.qmodel.tensors[p].bits(), plan.layers[&layer], "{p}");
            assert_eq!(loaded.qmodel.tensors[p], pm.qmodel.tensors[p], "{p}");
        }
    }
}

#[test]
fn mixed_precision_model_serves_through_the_deployment_executor() {
    // QuantizedBert's fused path must handle per-layer bit-widths: each
    // QLinear carries its own width, so a BitPlan artifact serves exactly
    // like the fake-quant eval view (within the fused-kernel idiom's 1e-3)
    let (cfg, store, batches, _) = tiny_setup();
    let sweep_cfg = SweepConfig::default();
    let table = sweep(&cfg, &store, &batches[..1], &sweep_cfg).unwrap();
    let plan = allocate(&table, table.uniform_bytes(4).unwrap()).unwrap();
    let artifact = QuantPipeline::new()
        .pass(AutoTunePass::new(plan, sweep_cfg.base))
        .run(&store)
        .unwrap();
    let qm = artifact.quantized_model();
    let reference =
        splitquant::model::BertModel::new(cfg.clone(), artifact.eval.share()).unwrap();
    let fused = splitquant::model::QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
    let b = &batches[0];
    let gap = reference
        .forward(&b.ids, &b.mask)
        .max_abs_diff(&fused.forward(&b.ids, &b.mask).unwrap());
    assert!(gap < 1e-3, "mixed-precision fused forward gap {gap}");
}

#[test]
fn bit_plan_json_roundtrip_through_disk() {
    let (cfg, store, batches, _) = tiny_setup();
    let table = sweep(&cfg, &store, &batches[..1], &SweepConfig::default()).unwrap();
    let plan = allocate(&table, table.uniform_bytes(4).unwrap()).unwrap();
    let path = std::env::temp_dir().join("sq_autotune_plan.json");
    plan.save(&path).unwrap();
    let loaded = BitPlan::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(plan.layers, loaded.layers);
    assert_eq!(plan.budget_bytes, loaded.budget_bytes);
    assert_eq!(plan.planned_bytes, loaded.planned_bytes);
    assert_eq!(plan.planned_kl.to_bits(), loaded.planned_kl.to_bits());

    // and a reloaded plan drives the pass identically
    let a = QuantPipeline::new()
        .pass(AutoTunePass::new(plan, SplitQuantConfig::new(2)))
        .run(&store)
        .unwrap();
    let b = QuantPipeline::new()
        .pass(AutoTunePass::new(loaded, SplitQuantConfig::new(2)))
        .run(&store)
        .unwrap();
    assert_eq!(a.quantized_model(), b.quantized_model());
}
