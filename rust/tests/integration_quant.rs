//! Integration: the whole PTQ pipeline (data → model → quantize → evaluate)
//! through the pure-Rust path, no artifacts required.

use splitquant::baselines;
use splitquant::data::{emotion, pad_to_batches, spam, HashTokenizer};
use splitquant::eval::{accuracy_rust, prepare_store, WeightMethod};
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::quant::QConfig;
use splitquant::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
use splitquant::util::rng::Rng;

fn tiny_cfg() -> BertConfig {
    BertConfig {
        vocab_size: 1024,
        hidden: 32,
        layers: 2,
        heads: 2,
        ffn: 64,
        max_len: 24,
        num_classes: 6,
        ln_eps: 1e-12,
    }
}

#[test]
fn full_pipeline_emotion() {
    let cfg = tiny_cfg();
    let mut rng = Rng::new(0);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let (_, test) = emotion::load_small(0, 10, 96);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, n) = pad_to_batches(&test, &tok, 32);
    assert_eq!(n, 96);

    for m in [
        WeightMethod::None,
        WeightMethod::Baseline(QConfig::baseline(2)),
        WeightMethod::SplitQuant(SplitQuantConfig::new(2)),
    ] {
        let (s, _) = prepare_store(&store, &m).unwrap();
        let acc = accuracy_rust(&cfg, &s, &batches, n, None).unwrap();
        assert!((0.0..=1.0).contains(&acc), "{}: {acc}", m.label());
    }
}

#[test]
fn int8_quantization_is_nearly_lossless_on_logits() {
    let cfg = tiny_cfg();
    let mut rng = Rng::new(1);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let (_, test) = emotion::load_small(1, 10, 32);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, _) = pad_to_batches(&test, &tok, 32);

    let (sq8, _) =
        prepare_store(&store, &WeightMethod::SplitQuant(SplitQuantConfig::new(8))).unwrap();
    let m_fp = splitquant::model::BertModel::new(cfg.clone(), store).unwrap();
    let m_q8 = splitquant::model::BertModel::new(cfg.clone(), sq8).unwrap();
    let b = &batches[0];
    let gap = m_fp.forward(&b.ids, &b.mask).max_abs_diff(&m_q8.forward(&b.ids, &b.mask));
    assert!(gap < 0.35, "INT8 logit gap too large: {gap}");
}

#[test]
fn splitquant_preserves_logits_better_than_baseline_at_int2() {
    let cfg = tiny_cfg();
    let mut rng = Rng::new(2);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let (_, test) = emotion::load_small(2, 10, 32);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, _) = pad_to_batches(&test, &tok, 32);
    let b = &batches[0];

    let m_fp = splitquant::model::BertModel::new(cfg.clone(), store.clone()).unwrap();
    let fp = m_fp.forward(&b.ids, &b.mask);

    let mut gaps = Vec::new();
    for m in [
        WeightMethod::Baseline(QConfig::baseline(2)),
        WeightMethod::SplitQuant(SplitQuantConfig::new(2)),
    ] {
        let (s, _) = prepare_store(&store, &m).unwrap();
        let mq = splitquant::model::BertModel::new(cfg.clone(), s).unwrap();
        let q = mq.forward(&b.ids, &b.mask);
        let mse: f64 = fp
            .data()
            .iter()
            .zip(q.data())
            .map(|(a, c)| ((a - c) as f64).powi(2))
            .sum::<f64>()
            / fp.numel() as f64;
        gaps.push(mse);
    }
    assert!(
        gaps[1] < gaps[0],
        "splitquant logit MSE {} must beat baseline {}",
        gaps[1],
        gaps[0]
    );
}

#[test]
fn spam_protocol_uses_full_corpus() {
    let d = spam::load_small(0, 200);
    assert_eq!(d.num_classes, 2);
    let tok = HashTokenizer::new(1024, 24);
    let (batches, n) = pad_to_batches(&d, &tok, 32);
    assert_eq!(n, 200);
    assert_eq!(batches.len(), 7);
}

#[test]
fn quantization_is_deterministic_given_seed() {
    let cfg = tiny_cfg();
    let mut rng = Rng::new(5);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = default_quantizable(&store);
    let sq = SplitQuantConfig::new(2);
    let (a, _) = quantize_store(&store, &quantizable, &sq).unwrap();
    let (b, _) = quantize_store(&store, &quantizable, &sq).unwrap();
    for (name, t) in a.iter() {
        assert_eq!(t.data(), b.get(name).unwrap().data(), "{name} differs across runs");
    }
}

#[test]
fn checkpoint_quantize_roundtrip() {
    // save → load → quantize must equal quantize of the original
    let cfg = tiny_cfg();
    let mut rng = Rng::new(6);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let path = std::env::temp_dir().join("sq_integration_ckpt.bin");
    store.save(&path).unwrap();
    let loaded = ParamStore::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let quantizable = default_quantizable(&store);
    let c = QConfig::baseline(4);
    let (qa, _) = baselines::quantize_store_baseline(&store, &quantizable, &c).unwrap();
    let (qb, _) = baselines::quantize_store_baseline(&loaded, &quantizable, &c).unwrap();
    for (name, t) in qa.iter() {
        assert_eq!(t.data(), qb.get(name).unwrap().data());
    }
}

#[test]
fn effect_grows_as_bits_shrink() {
    // the paper's headline trend: SplitQuant's advantage (in weight
    // reconstruction error) grows as bit-width decreases
    let cfg = tiny_cfg();
    let mut rng = Rng::new(7);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = default_quantizable(&store);

    let mut ratios = Vec::new();
    for bits in [8u8, 4, 2] {
        let (base, _) = baselines::quantize_store_baseline(
            &store,
            &quantizable,
            &QConfig::baseline(bits),
        )
        .unwrap();
        let (sq, _) =
            quantize_store(&store, &quantizable, &SplitQuantConfig::new(bits)).unwrap();
        let mse = |s: &ParamStore| -> f64 {
            quantizable
                .iter()
                .map(|n| {
                    let o = store.get(n).unwrap();
                    let q = s.get(n).unwrap();
                    o.data()
                        .iter()
                        .zip(q.data())
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum::<f64>()
                })
                .sum()
        };
        ratios.push(mse(&sq) / mse(&base));
    }
    // lower ratio = bigger SplitQuant win; must improve (or hold) as bits drop
    assert!(
        ratios[2] <= ratios[0] + 0.05,
        "INT2 ratio {} should beat INT8 ratio {}",
        ratios[2],
        ratios[0]
    );
}
