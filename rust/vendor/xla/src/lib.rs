//! Offline stub of the `xla` PJRT bindings.
//!
//! The container this repository builds in has no libxla and no registry
//! access, so this path crate mirrors the API surface the runtime layer
//! (`splitquant::runtime`) compiles against. Every entry point that would
//! need the real backend fails cleanly at `PjRtClient::cpu()`, which the
//! callers already treat as "artifacts unavailable — skip": integration
//! tests and benches print a SKIP line, and the serving stack falls back to
//! the pure-Rust executor.
//!
//! Swap this path dependency for the real `xla` crate (same names, same
//! signatures) to light up the PJRT paths — no source change needed.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's shape (opaque message).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error::new(
        "PJRT backend unavailable: this build uses the offline xla stub \
         (vendor/xla); artifact-backed executables cannot run",
    )
}

/// Element types the literal layer converts between.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    S8,
}

/// Rust scalar types that can back a literal buffer.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i8 {}

/// Host-side tensor literal. In the stub it carries no data: literals are
/// only ever consumed by `execute`, which cannot be reached without a
/// client, so conversion methods that *produce* data return errors.
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal(()))
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal, Error> {
        Ok(Literal(()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module. Construction requires the real parser, so the stub
/// constructor fails; no instance can exist.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Computation wrapper fed to `PjRtClient::compile`.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer returned by `execute`.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Compiled executable. Only obtainable through `PjRtClient::compile`,
/// which is unreachable in the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// PJRT client handle. `cpu()` is the single entry point and it fails in
/// the stub, so every downstream method is unreachable in practice (their
/// bodies return inert placeholders to keep the surface total).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_roundtrip_is_inert() {
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
