//! Offline stub of the `log` facade.
//!
//! The sandbox has no registry access, so this path crate provides the five
//! level macros with the same invocation syntax as the real crate. Records
//! go to stderr only when `SPLITQUANT_LOG` is set in the environment, so the
//! request path stays silent by default. Arguments are always evaluated
//! (matching the real facade closely enough for `-D warnings` builds).

/// Backing sink for the level macros. Not part of the public API surface of
/// the real crate; named with a double underscore to signal that.
pub fn __log(level: &str, args: std::fmt::Arguments<'_>) {
    if std::env::var_os("SPLITQUANT_LOG").is_some() {
        eprintln!("[{level}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__log("ERROR", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__log("WARN", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__log("INFO", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__log("DEBUG", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__log("TRACE", format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_expand_and_evaluate_args() {
        let mut hits = 0;
        let mut bump = || {
            hits += 1;
            hits
        };
        crate::info!("value {}", bump());
        crate::error!("value {}", bump());
        assert_eq!(hits, 2, "macro arguments must be evaluated");
    }
}
