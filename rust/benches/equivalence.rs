//! Bench F1/F2/F3: the paper's **Figures 1–3** as runnable experiments —
//! structural equivalence of split layers (linear / activation / conv), plus
//! the runtime overhead of the literal three-branch form vs the fused form.
//!
//! ```sh
//! cargo bench --bench equivalence
//! ```

use std::time::Instant;

use splitquant::model::graph::Layer;
use splitquant::report::Table;
use splitquant::splitquant::equivalence::{
    check_activation_equivalence, check_conv_equivalence, check_linear_equivalence,
    split_linear_layer,
};
use splitquant::splitquant::{split_quantize_pair, SplitQuantConfig};
use splitquant::tensor::Tensor;
use splitquant::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);

    // ---- F2: linear split equivalence + quantization error across shapes
    let mut f2 = Table::new(
        "Figure 2 — split linear: FP32 identity & INT-b error vs baseline",
        &["shape", "bits", "fp32 gap", "fused-vs-branches", "split err", "baseline err"],
    );
    for &(ni, no) in &[(128usize, 128usize), (128, 512), (512, 128)] {
        for bits in [2u8, 4, 8] {
            let cfg = SplitQuantConfig::new(bits);
            let r = check_linear_equivalence(ni, no, 32, &cfg, &mut rng);
            f2.row(vec![
                format!("{ni}x{no}"),
                format!("INT{bits}"),
                format!("{:.1e}", r.fp32_gap),
                format!("{:.1e}", r.fused_vs_branches_gap),
                format!("{:.3}", r.quant_error_split),
                format!("{:.3}", r.quant_error_baseline),
            ]);
            assert!(r.fp32_gap < 1e-3, "split must be mathematically equivalent");
        }
    }
    println!("{}", f2.render());

    // ---- F1(D): activation split identity
    let mut f1 = Table::new(
        "Figure 1(D) — activation split/concat identity (GELU)",
        &["width", "max gap"],
    );
    for w in [128usize, 512, 7, 1000] {
        let gap = check_activation_equivalence(w, 16, &mut rng);
        f1.row(vec![w.to_string(), format!("{gap:.1e}")]);
    }
    println!("{}", f1.render());

    // ---- F3: conv split equivalence
    let mut f3 = Table::new(
        "Figure 3 — conv split: fused dequant vs 3 materialized conv branches",
        &["bits", "max gap"],
    );
    for bits in [2u8, 4, 8] {
        let gap = check_conv_equivalence(&SplitQuantConfig::new(bits), &mut rng);
        f3.row(vec![format!("INT{bits}"), format!("{gap:.1e}")]);
    }
    println!("{}", f3.render());

    // ---- overhead: original vs literal 3-branch vs fused execution
    let mut ov = Table::new(
        "execution cost: original vs materialized 3-branch vs fused dequant (128x512, batch 64, 200 reps)",
        &["form", "time", "vs original"],
    );
    let w = Tensor::randn(&[128, 512], 0.0, 0.5, &mut rng);
    let b = Tensor::randn(&[512], 0.0, 0.5, &mut rng);
    let x = Tensor::randn(&[64, 128], 0.0, 1.0, &mut rng);
    let sqc = SplitQuantConfig::new(2);
    let (ws, bs) = split_quantize_pair(&w, Some(&b), &sqc, &mut rng).unwrap();
    let bs = bs.unwrap();
    let orig = Layer::Linear { weight: w.clone(), bias: Some(b.clone()) };
    let split3 = split_linear_layer(&w, Some(&b), &ws, Some(&bs), 3);
    let fused =
        Layer::Linear { weight: ws.qtensor.dequantize(), bias: Some(bs.qtensor.dequantize()) };

    let time = |l: &Layer| {
        let t0 = Instant::now();
        for _ in 0..200 {
            std::hint::black_box(l.forward(&x));
        }
        t0.elapsed()
    };
    let t_orig = time(&orig);
    let t_split = time(&split3);
    let t_fused = time(&fused);
    ov.row(vec!["original linear".into(), format!("{t_orig:?}"), "1.00x".into()]);
    ov.row(vec![
        "3 dense branches (paper literal)".into(),
        format!("{t_split:?}"),
        format!("{:.2}x", t_split.as_secs_f64() / t_orig.as_secs_f64()),
    ]);
    ov.row(vec![
        "fused codes+cid (ours)".into(),
        format!("{t_fused:?}"),
        format!("{:.2}x", t_fused.as_secs_f64() / t_orig.as_secs_f64()),
    ]);
    println!("{}", ov.render());
    println!("shape expectation: fp32 gaps ~1e-5 (exact up to f32 addition order);");
    println!("3-branch ≈ 3x original (the §6 overhead); fused ≈ 1x (zeros never materialized).");
}
