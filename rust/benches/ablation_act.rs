//! Bench A3 (ablation): activation quantization — none vs per-tensor vs
//! SplitQuant activation splitting (§4.2), on top of SplitQuant weights.
//! Includes the §4.2 note: weight-only quantizers (Quanto default) should
//! skip activation splitting entirely.
//!
//! When artifacts are present, the per-tensor and split rows are also run
//! through the AOT act-quant executable (the L1 Pallas fake-quant kernel on
//! the request path) to cross-check the two engines.
//!
//! ```sh
//! cargo bench --bench ablation_act
//! ```

use std::path::Path;

use splitquant::data::{emotion, pad_to_batches, HashTokenizer};
use splitquant::eval::{accuracy_pjrt_actquant, accuracy_rust, calibrate, prepare_store, WeightMethod};
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::report::{pct, Table};
use splitquant::runtime::Runtime;
use splitquant::splitquant::{ActQuantMode, SplitQuantConfig};
use splitquant::util::rng::Rng;

fn main() {
    let cfg = BertConfig::default();
    let store = if Path::new("checkpoints/emotion.bin").exists() {
        ParamStore::load(Path::new("checkpoints/emotion.bin")).unwrap()
    } else {
        eprintln!("[ablation_act] no checkpoint; using random init");
        ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(0))
    };
    let (_, test) = emotion::load(0);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, n) = pad_to_batches(&test, &tok, 32);
    let rt = Runtime::new(Path::new("artifacts")).ok();
    if rt.is_none() {
        eprintln!("[ablation_act] no artifacts: PJRT cross-check disabled");
    }

    // calibrate on 8 batches of the test distribution (paper's setup uses
    // whatever data is at hand; ranges are what matters)
    let cal = calibrate(&cfg, &store, &batches[..8.min(batches.len())]).unwrap();

    let mut t = Table::new(
        "A3 — activation quantization on emotion (weights: SplitQuant at same bits)",
        &["bits", "act=none", "act per-tensor", "act split (§4.2)", "pjrt split"],
    );
    for bits in [2u8, 4, 8] {
        let (wq, _) =
            prepare_store(&store, &WeightMethod::SplitQuant(SplitQuantConfig::new(bits)))
                .unwrap();
        let none = accuracy_rust(&cfg, &wq, &batches, n, None).unwrap();
        let pt = cal.to_params(bits, ActQuantMode::PerTensor);
        let acc_pt = accuracy_rust(&cfg, &wq, &batches, n, Some(&pt)).unwrap();
        let sp = cal.to_params(bits, ActQuantMode::Split);
        let acc_sp = accuracy_rust(&cfg, &wq, &batches, n, Some(&sp)).unwrap();
        let pjrt = match &rt {
            Some(rt) => {
                let a = accuracy_pjrt_actquant(rt, &wq, &batches, n, &sp).unwrap();
                pct(a)
            }
            None => "-".into(),
        };
        t.row(vec![format!("INT{bits}"), pct(none), pct(acc_pt), pct(acc_sp), pjrt]);
    }
    println!("{}", t.render());
    println!("{}", t.render_markdown());
    println!(
        "shape expectation: act splitting >= per-tensor act quant, gap largest at\n\
         INT2; act=none is the §4.2 weight-only regime (skip splitting there)."
    );
}
