//! Bench P0 (§Perf): microbenchmarks of the L3 hot paths that dominate the
//! Table-1 sweep and the serving loop — blocked matmul (scalar vs f32x8
//! engines, serial vs pooled), the fused split-dequant matmul, quantize/
//! dequantize, plane unpack, 1-D k-means (fast vs generic), and the BERT
//! executor forward.
//!
//! Besides the human table, the engine rows merge into
//! `BENCH_kernels.json` (shape, engine, ns/iter, GB/s) so the perf
//! trajectory is tracked across PRs — acceptance: the SIMD engine beats
//! the scalar engine on the pooled 512³ row and a fused split-dequant row.
//!
//! ```sh
//! cargo bench --bench kernel_hotpath
//! ```

use std::time::Instant;

use splitquant::clustering::init::greedy_kmeanspp;
use splitquant::clustering::kmeans::lloyd_generic;
use splitquant::clustering::kmeans1d::lloyd_fast;
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::model::BertModel;
use splitquant::parallel::{self, kernels, KernelKind, ParallelConfig};
use splitquant::quant::{QConfig, QTensor};
use splitquant::report::bench_json::{merge_write, BenchRecord};
use splitquant::report::Table;
use splitquant::tensor::{ops, IntTensor, Tensor};
use splitquant::util::rng::Rng;

fn time_n(n: usize, mut f: impl FnMut()) -> std::time::Duration {
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed() / n as u32
}

fn main() {
    // pin the pool: the acceptance criterion is serial vs 8 kernel threads
    // (override with SPLITQUANT_THREADS after changing `threads` to 0)
    parallel::configure(ParallelConfig { threads: 8, ..ParallelConfig::default() });
    let mut rng = Rng::new(0);
    let mut t = Table::new("§Perf — L3 hot-path microbenchmarks", &["op", "time", "rate"]);
    let mut json: Vec<BenchRecord> = Vec::new();

    // ---- kernel engines on 512×512×512: {serial, pool×8} × {scalar, simd,
    //      int8}. On plain f32×f32 matmuls the int8 engine rides the f32x8
    //      kernels (there are no packed codes to consume), so its rows pin
    //      the dispatch overhead of the engine knob, not an integer datapath
    //      — the integer rows live in the fused section below.
    {
        let (m, k, n) = (512usize, 512usize, 512usize);
        let shape = format!("{m}x{k}x{n}");
        let a = Tensor::randn(&[m, k], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 0.0, 1.0, &mut rng);
        let bytes = (m * k + k * n + m * n) * 4;
        let gflops = |d: std::time::Duration| 2.0 * (m * k * n) as f64 / d.as_secs_f64() / 1e9;
        let mut times = Vec::new();
        for (engine, kind, pooled) in [
            ("serial-scalar", KernelKind::Scalar, false),
            ("serial-simd", KernelKind::Simd, false),
            ("serial-int8", KernelKind::Int8, false),
            ("pool8-scalar", KernelKind::Scalar, true),
            ("pool8-simd", KernelKind::Simd, true),
            ("pool8-int8", KernelKind::Int8, true),
        ] {
            let d = time_n(5, || {
                if pooled {
                    std::hint::black_box(kernels::matmul_with(&a, &b, kind));
                } else {
                    std::hint::black_box(ops::matmul_serial_with(&a, &b, kind));
                }
            });
            t.row(vec![
                format!("matmul {shape} {engine}"),
                format!("{d:.2?}"),
                format!("{:.2} GFLOP/s", gflops(d)),
            ]);
            json.push(
                BenchRecord::new("matmul", &shape, engine, d, bytes).with("gflops", gflops(d)),
            );
            times.push((engine, d));
        }
        let get = |e: &str| times.iter().find(|(n, _)| *n == e).unwrap().1.as_secs_f64();
        t.row(vec![
            format!("matmul {shape} speedups"),
            "-".into(),
            format!(
                "pool8 {:.1}x vs serial (same engine); simd {:.2}x vs scalar \
                 pooled (acceptance: pool >= 3x, simd > 1x)",
                get("serial-scalar") / get("pool8-scalar"),
                get("pool8-scalar") / get("pool8-simd"),
            ),
        ]);
    }

    // ---- serial matmul (the historical single-core baseline rows; the
    //      pool engine is measured separately above — ops::matmul would
    //      now dispatch these shapes to the pool and skew the comparison)
    for &(m, k, n) in &[(2048usize, 128usize, 128usize), (2048, 128, 512), (2048, 512, 128)] {
        let a = Tensor::randn(&[m, k], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 0.0, 1.0, &mut rng);
        let d = time_n(5, || {
            std::hint::black_box(ops::matmul_serial(&a, &b));
        });
        let gflops = 2.0 * (m * k * n) as f64 / d.as_secs_f64() / 1e9;
        t.row(vec![
            format!("matmul {m}x{k}x{n} serial"),
            format!("{d:.2?}"),
            format!("{gflops:.2} GFLOP/s"),
        ]);
    }

    // ---- fused split-dequant matmul: tiles dequantized on the fly vs
    //      materializing FP32 weights then running the serial kernel, and
    //      the scalar vs f32x8 fused engines on a real Split layout
    {
        let (m, k, n) = (2048usize, 512usize, 512usize);
        let shape = format!("{m}x{k}x{n}");
        let x = Tensor::randn(&[m, k], 0.0, 1.0, &mut rng);
        let w = Tensor::randn(&[k, n], 0.0, 0.1, &mut rng);
        let q = QTensor::quantize(&w, &QConfig::baseline(2)).unwrap();
        let d_mat = time_n(5, || {
            let dq = q.dequantize();
            std::hint::black_box(ops::matmul_serial(&x, &dq));
        });
        t.row(vec![
            format!("dequant+matmul {m}x{k}x{n} INT2"),
            format!("{d_mat:.2?}"),
            "-".into(),
        ]);
        // streaming bytes of the fused kernel: x + codes (+cid) + out
        let (codes, cid) = q.fused_planes().unwrap();
        let bytes = m * k * 4 + codes.len() + cid.len() + m * n * 4;
        let mut times = Vec::new();
        for (engine, kind) in
            [("pool8-scalar", KernelKind::Scalar), ("pool8-simd", KernelKind::Simd)]
        {
            let d = time_n(5, || {
                std::hint::black_box(kernels::split_matmul_pooled_with(
                    &x,
                    q.shape(),
                    &codes,
                    &cid,
                    q.params(),
                    kind,
                ));
            });
            t.row(vec![
                format!("fused split matmul {shape} INT2 {engine}"),
                format!("{d:.2?}"),
                format!("{:.1}x vs dequant+serial", d_mat.as_secs_f64() / d.as_secs_f64()),
            ]);
            json.push(BenchRecord::new("fused-split-matmul", &shape, engine, d, bytes));
            times.push(d);
        }
        t.row(vec![
            format!("fused split matmul {shape} speedup"),
            "-".into(),
            format!(
                "simd {:.2}x vs scalar pooled (acceptance: > 1x)",
                times[0].as_secs_f64() / times[1].as_secs_f64()
            ),
        ]);

        // the PR-6 integer datapath on the same per-tensor INT2 planes:
        // activations quantized to i8 per call, raw codes consumed by the
        // i8×i8→i32 kernel, weight zero-points folded into the epilogue.
        // `scalar-int8` is the always-serial scalar reference twin — the
        // bit-exactness oracle doubling as the single-core baseline row.
        // streamed bytes: i16 activation plane + codes (+cid) + f32 out
        let bytes_i8 = m * k * 2 + codes.len() + cid.len() + m * n * 4;
        for (engine, int8_ref) in [("pool8-int8", false), ("scalar-int8", true)] {
            let d = time_n(5, || {
                std::hint::black_box(if int8_ref {
                    kernels::split_matmul_int8_reference(
                        &x,
                        q.shape(),
                        &codes,
                        &cid,
                        q.params(),
                        None,
                    )
                } else {
                    kernels::split_matmul_int8(&x, q.shape(), &codes, &cid, q.params(), None)
                });
            });
            t.row(vec![
                format!("fused int8 matmul {shape} INT2 {engine}"),
                format!("{d:.2?}"),
                format!("{:.1}x vs dequant+serial", d_mat.as_secs_f64() / d.as_secs_f64()),
            ]);
            json.push(BenchRecord::new("fused-split-matmul", &shape, engine, d, bytes_i8));
        }

        // a Split-layout (cluster-id) fused row: 3 scale groups, 2-bit cid
        // plane — the SplitQuant deployment shape
        let groups = [
            splitquant::quant::QParams::from_range(-0.05, 0.05, 2),
            splitquant::quant::QParams::from_range(-0.5, 0.5, 2),
            splitquant::quant::QParams::from_range(-4.0, 4.0, 2),
        ];
        let cid3: Vec<u8> = (0..k * n).map(|i| (i % 3) as u8).collect();
        for (engine, kind) in
            [("pool8-scalar", KernelKind::Scalar), ("pool8-simd", KernelKind::Simd)]
        {
            let d = time_n(5, || {
                std::hint::black_box(kernels::split_matmul_pooled_with(
                    &x,
                    q.shape(),
                    &codes,
                    &cid3,
                    &groups,
                    kind,
                ));
            });
            t.row(vec![
                format!("fused split matmul {shape} INT2 3-cluster {engine}"),
                format!("{d:.2?}"),
                "-".into(),
            ]);
            json.push(BenchRecord::new(
                "fused-split-matmul-3cluster",
                &shape,
                engine,
                d,
                m * k * 4 + codes.len() + cid3.len() + m * n * 4,
            ));
        }

        // integer datapath on the 3-cluster Split layout: per-element cid
        // gather + per-cluster i32 code-sum correction in the epilogue
        for (engine, int8_ref) in [("pool8-int8", false), ("scalar-int8", true)] {
            let d = time_n(5, || {
                std::hint::black_box(if int8_ref {
                    kernels::split_matmul_int8_reference(
                        &x,
                        q.shape(),
                        &codes,
                        &cid3,
                        &groups,
                        None,
                    )
                } else {
                    kernels::split_matmul_int8(&x, q.shape(), &codes, &cid3, &groups, None)
                });
            });
            t.row(vec![
                format!("fused int8 matmul {shape} INT2 3-cluster {engine}"),
                format!("{d:.2?}"),
                "-".into(),
            ]);
            json.push(BenchRecord::new(
                "fused-split-matmul-3cluster",
                &shape,
                engine,
                d,
                m * k * 2 + codes.len() + cid3.len() + m * n * 4,
            ));
        }
    }

    // ---- plane unpack: the byte-LUT fast path feeding the fused kernels
    {
        let numel = 1 << 20;
        let codes: Vec<i8> = (0..numel).map(|i| ((i % 4) as i8) - 2).collect();
        for bits in [2u8, 4] {
            let p = splitquant::tensor::packing::Packed::pack(&codes, bits).unwrap();
            let d = time_n(20, || {
                std::hint::black_box(p.unpack());
            });
            t.row(vec![
                format!("unpack 1M INT{bits} (LUT)"),
                format!("{d:.2?}"),
                format!("{:.0} Melem/s", 1.048_576 / d.as_secs_f64()),
            ]);
            json.push(BenchRecord::new(
                "plane-unpack",
                &format!("1M-int{bits}"),
                "lut",
                d,
                p.byte_size() + numel,
            ));
        }
    }

    // ---- quantize / dequantize a 1M-element tensor
    let big = Tensor::randn(&[1024, 1024], 0.0, 1.0, &mut rng);
    for bits in [2u8, 8] {
        let cfg = QConfig::baseline(bits);
        let d = time_n(5, || {
            std::hint::black_box(QTensor::quantize(&big, &cfg).unwrap());
        });
        t.row(vec![
            format!("quantize 1M INT{bits}"),
            format!("{d:.2?}"),
            format!("{:.0} Melem/s", 1.048_576 / d.as_secs_f64()),
        ]);
        let q = QTensor::quantize(&big, &cfg).unwrap();
        let d = time_n(5, || {
            std::hint::black_box(q.dequantize());
        });
        t.row(vec![
            format!("dequantize 1M INT{bits}"),
            format!("{d:.2?}"),
            format!("{:.0} Melem/s", 1.048_576 / d.as_secs_f64()),
        ]);
    }

    // ---- k-means on the embedding-table scale (1M values)
    let values: Vec<f32> = (0..1_048_576).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let init = greedy_kmeanspp(&values[..65536], 3, &mut rng); // seed on a sample
    let d_fast = time_n(3, || {
        std::hint::black_box(lloyd_fast(&values, &init, 50));
    });
    t.row(vec!["kmeans1d fast 1M k=3".into(), format!("{d_fast:.2?}"), "-".into()]);
    let d_gen = time_n(1, || {
        std::hint::black_box(lloyd_generic(&values, &init, 50));
    });
    t.row(vec![
        "kmeans generic 1M k=3".into(),
        format!("{d_gen:.2?}"),
        format!("fast is {:.1}x faster", d_gen.as_secs_f64() / d_fast.as_secs_f64()),
    ]);

    // ---- full BERT-Tiny forward (batch 32) through the Rust executor
    let cfg = BertConfig::default();
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let model = BertModel::new(cfg.clone(), store).unwrap();
    let ids = IntTensor::new(
        &[32, 64],
        (0..32 * 64).map(|_| rng.below(cfg.vocab_size) as i32).collect(),
    )
    .unwrap();
    let mask = Tensor::full(&[32, 64], 1.0);
    let d = time_n(5, || {
        std::hint::black_box(model.forward(&ids, &mask));
    });
    t.row(vec![
        "BERT-Tiny fwd b32 (rust executor)".into(),
        format!("{d:.2?}"),
        format!("{:.0} samples/s", 32.0 / d.as_secs_f64()),
    ]);

    // ---- fused quantized executor (deployment path: dequant inside matmul)
    {
        use splitquant::model::QuantizedBert;
        use splitquant::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
        let store2 = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store2);
        let (eval_store, qm) = quantize_store(&store2, &q, &SplitQuantConfig::new(2)).unwrap();
        let qmodel = QuantizedBert::new(cfg.clone(), &store2, &qm).unwrap();
        let d = time_n(5, || {
            std::hint::black_box(qmodel.forward(&ids, &mask).unwrap());
        });
        t.row(vec![
            "QuantizedBert fwd b32 (fused INT2 dequant)".into(),
            format!("{d:.2?}"),
            format!(
                "{:.0} samples/s, weights {:.0}% of FP32 resident",
                32.0 / d.as_secs_f64(),
                100.0 * qmodel.quantized_resident_bytes() as f64
                    / qmodel.fp32_equivalent_bytes() as f64
            ),
        ]);

        // the same packed model on the int8 engine: throughput + fidelity.
        // Agreement is top-1 vs the FP32 reference over held-out batches —
        // the f32 fused engine's agreement is recorded next to it so the
        // json separates weight-quantization loss from integer-datapath loss
        let mut qint8 = QuantizedBert::new(cfg.clone(), &store2, &qm).unwrap();
        qint8.set_kernel(KernelKind::Int8);
        let d_i8 = time_n(5, || {
            std::hint::black_box(qint8.forward(&ids, &mask).unwrap());
        });
        t.row(vec![
            "QuantizedBert fwd b32 (int8 engine)".into(),
            format!("{d_i8:.2?}"),
            format!("{:.0} samples/s", 32.0 / d_i8.as_secs_f64()),
        ]);
        {
            use splitquant::data::{emotion, pad_to_batches, HashTokenizer};
            use splitquant::eval;
            let (_, test) = emotion::load_small(0, 10, 128);
            let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
            let (batches, n) = pad_to_batches(&test, &tok, 32);
            let refs = eval::predictions_rust(&cfg, &store2, &batches, n).unwrap();
            let a_i8 = eval::agreement_int8(&cfg, &refs, &store2, &qm, &batches, n, None).unwrap();
            let a_f32 = eval::agreement_rust(&cfg, &store2, &eval_store, &batches, n).unwrap();
            t.row(vec![
                "QuantizedBert agreement vs FP32 (INT2 weights)".into(),
                "-".into(),
                format!("int8 engine {a_i8:.3}, f32 engine {a_f32:.3} over {n} examples"),
            ]);
            json.push(
                BenchRecord::new("qbert-agreement-vs-fp32", "bert-tiny-int2", "int8", d_i8, 0)
                    .with("agreement", a_i8),
            );
            json.push(
                BenchRecord::new("qbert-agreement-vs-fp32", "bert-tiny-int2", "f32", d, 0)
                    .with("agreement", a_f32),
            );
        }
    }

    println!("{}", t.render());
    println!("{}", t.render_markdown());

    let path = std::path::Path::new("BENCH_kernels.json");
    match merge_write(path, &json) {
        Ok(()) => println!("[kernel_hotpath] wrote {} records to {}", json.len(), path.display()),
        Err(e) => eprintln!("[kernel_hotpath] could not write {}: {e}", path.display()),
    }
}
