//! Bench T1: regenerate the paper's **Table 1** — BERT-Tiny accuracy on the
//! emotion and spam tasks at FP32 / INT2 / INT4 / INT8, baseline quantizer
//! vs SplitQuant, with the paper's published numbers printed alongside for
//! shape comparison.
//!
//! ```sh
//! cargo bench --bench table1
//! ```
//! Uses `checkpoints/{emotion,spam}.bin` (produce them with
//! `cargo run --release --example train_and_quantize` or `splitquant train`).

use std::path::Path;

use splitquant::data::{emotion, pad_to_batches, spam, HashTokenizer};
use splitquant::eval::{accuracy_rust, prepare_store, WeightMethod};
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::quant::QConfig;
use splitquant::report::{pct, pct_delta, Table};
use splitquant::splitquant::SplitQuantConfig;

/// Paper Table 1 values: (dataset, fp32, [(bits, baseline, splitquant)]).
const PAPER: &[(&str, f64, &[(u8, f64, f64)])] = &[
    ("emotion", 0.902, &[(2, 0.865, 0.898), (4, 0.900, 0.902), (8, 0.902, 0.903)]),
    ("spam", 0.984, &[(2, 0.962, 0.983), (4, 0.983, 0.984), (8, 0.984, 0.984)]),
];

fn main() {
    let cfg = BertConfig::default();
    let mut table = Table::new(
        "Table 1 reproduction — BERT-Tiny, baseline vs SplitQuant (paper values in brackets)",
        &["Dataset", "FP32", "Bits", "Baseline", "SplitQuant", "Diff", "Paper diff"],
    );
    let t0 = std::time::Instant::now();
    for (task, paper_fp32, paper_rows) in PAPER {
        let ckpt = format!("checkpoints/{task}.bin");
        if !Path::new(&ckpt).exists() {
            eprintln!("[table1] SKIP {task}: no {ckpt} (train first)");
            continue;
        }
        let store = ParamStore::load(Path::new(&ckpt)).expect("checkpoint");
        let test_set = match *task {
            "spam" => spam::load(0),
            _ => emotion::load(0).1,
        };
        let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
        let (batches, n) = pad_to_batches(&test_set, &tok, 32);
        let fp32 = accuracy_rust(&cfg, &store, &batches, n, None).unwrap();
        for &(bits, p_base, p_sq) in *paper_rows {
            let (bs, _) = prepare_store(&store, &WeightMethod::Baseline(QConfig::baseline(bits)))
                .unwrap();
            let base = accuracy_rust(&cfg, &bs, &batches, n, None).unwrap();
            let (ss, _) =
                prepare_store(&store, &WeightMethod::SplitQuant(SplitQuantConfig::new(bits)))
                    .unwrap();
            let sq = accuracy_rust(&cfg, &ss, &batches, n, None).unwrap();
            table.row(vec![
                task.to_string(),
                format!("{} [{}]", pct(fp32), pct(*paper_fp32)),
                format!("INT{bits}"),
                format!("{} [{}]", pct(base), pct(p_base)),
                format!("{} [{}]", pct(sq), pct(p_sq)),
                pct_delta(sq - base),
                pct_delta(p_sq - p_base),
            ]);
        }
    }
    println!("{}", table.render());
    println!("{}", table.render_markdown());
    println!("elapsed: {:?}", t0.elapsed());
    println!(
        "expected shape: SplitQuant >= baseline everywhere; the gap is largest at\n\
         INT2 and vanishes by INT8; SplitQuant INT2 lands near FP32 (paper §5/§6)."
    );
}
