//! Bench A2 (ablation): *how* to split matters — k-means (the paper, §4.1)
//! vs equal-width range partition vs quantile (equal-population) partition,
//! all at k=3, INT2, on the emotion checkpoint.
//!
//! ```sh
//! cargo bench --bench ablation_split
//! ```

use std::path::Path;

use splitquant::data::{emotion, pad_to_batches, HashTokenizer};
use splitquant::eval::accuracy_rust;
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::report::{pct, Table};
use splitquant::splitquant::weight_split::{
    assign_equal_width, assign_quantile, split_quantize_with_assignment,
};
use splitquant::splitquant as sq;
use splitquant::splitquant::SplitQuantConfig;
use splitquant::util::rng::Rng;

fn quantize_with(
    store: &ParamStore,
    quantizable: &[String],
    bits: u8,
    assigner: &dyn Fn(&[f32]) -> Vec<u8>,
) -> ParamStore {
    let mut eval = store.clone();
    for name in quantizable {
        let t = store.get(name).unwrap();
        let a = assigner(t.data());
        let st = split_quantize_with_assignment(t, a, 3, bits).unwrap();
        eval.set(name, st.qtensor.dequantize()).unwrap();
    }
    eval
}

fn main() {
    let cfg = BertConfig::default();
    let store = if Path::new("checkpoints/emotion.bin").exists() {
        ParamStore::load(Path::new("checkpoints/emotion.bin")).unwrap()
    } else {
        eprintln!("[ablation_split] no checkpoint; using random init");
        ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(0))
    };
    let (_, test) = emotion::load(0);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, n) = pad_to_batches(&test, &tok, 32);
    let fp32 = accuracy_rust(&cfg, &store, &batches, n, None).unwrap();
    let quantizable = sq::default_quantizable(&store);

    let recon = |eval: &ParamStore| -> f64 {
        quantizable
            .iter()
            .map(|name| {
                let o = store.get(name).unwrap();
                let q = eval.get(name).unwrap();
                o.data()
                    .iter()
                    .zip(q.data())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
            })
            .sum()
    };

    let mut t = Table::new(
        &format!("A2 — split strategy at INT2, k=3 (FP32 {})", pct(fp32)),
        &["strategy", "accuracy", "recon MSE"],
    );
    for bits in [2u8, 4] {
        // k-means (the paper)
        let (km_store, _) = sq::quantize_store(
            &store,
            &quantizable,
            &SplitQuantConfig::new(bits),
        )
        .unwrap();
        let acc = accuracy_rust(&cfg, &km_store, &batches, n, None).unwrap();
        t.row(vec![
            format!("k-means++ (paper) INT{bits}"),
            pct(acc),
            format!("{:.3}", recon(&km_store)),
        ]);

        let ew = quantize_with(&store, &quantizable, bits, &|v| assign_equal_width(v, 3));
        let acc = accuracy_rust(&cfg, &ew, &batches, n, None).unwrap();
        t.row(vec![
            format!("equal-width INT{bits}"),
            pct(acc),
            format!("{:.3}", recon(&ew)),
        ]);

        let qt = quantize_with(&store, &quantizable, bits, &|v| assign_quantile(v, 3));
        let acc = accuracy_rust(&cfg, &qt, &batches, n, None).unwrap();
        t.row(vec![
            format!("quantile INT{bits}"),
            pct(acc),
            format!("{:.3}", recon(&qt)),
        ]);

        // A2b: joint weight+bias clustering (one k-means per layer) — the
        // naive reading of Figure 2; hurts when bias magnitudes differ
        let mut joint = SplitQuantConfig::new(bits);
        joint.joint_bias = true;
        let (j_store, _) = sq::quantize_store(&store, &quantizable, &joint).unwrap();
        let acc = accuracy_rust(&cfg, &j_store, &batches, n, None).unwrap();
        t.row(vec![
            format!("k-means joint w+b INT{bits} (A2b)"),
            pct(acc),
            format!("{:.3}", recon(&j_store)),
        ]);
    }
    println!("{}", t.render());
    println!("{}", t.render_markdown());
    println!(
        "shape expectation: k-means minimizes within-cluster variance and should\n\
         win or tie on reconstruction; equal-width collapses under outliers\n\
         (most mass in one bin); quantile wastes range on dense regions."
    );
}
