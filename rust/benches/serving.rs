//! Bench S1: serving throughput / latency through the coordinator + PJRT
//! executables (the L3 system contribution), sweeping offered concurrency
//! and worker count. Skipped without artifacts.
//!
//! Bench S0 (paged vs resident quantized serving) is artifact-free and
//! always runs: the same SplitQuant INT2 model served fully resident and
//! under shrinking shard-residency budgets ([`splitquant::shardstore`]).
//!
//! ```sh
//! cargo bench --bench serving
//! ```

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use splitquant::coordinator::{PjrtExecutor, QuantExecutor, ServeConfig, Server};
use splitquant::data::{emotion, HashTokenizer};
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::quant::PackedModel;
use splitquant::report::bench_json::{merge_write, BenchRecord};
use splitquant::report::Table;
use splitquant::runtime::Runtime;
use splitquant::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
use splitquant::util::rng::Rng;

/// S0 — the cost of paging: one quantized model, one traffic pattern,
/// residency budgets from ∞ down to 25 % of the pagable encoder bytes.
fn paged_vs_resident() {
    let cfg = BertConfig {
        vocab_size: 4096,
        hidden: 64,
        layers: 2,
        heads: 2,
        ffn: 128,
        max_len: 32,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let store = ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(7));
    let quantizable = default_quantizable(&store);
    let (_, qm) =
        quantize_store(&store, &quantizable, &SplitQuantConfig::new(2)).unwrap();
    let pm = PackedModel::assemble(&store, &qm);
    let shards = std::env::temp_dir().join("sq_bench_serving.sqsh");
    pm.save_sharded(&shards).unwrap();
    // budgets are % of the *pagable* bytes (the encoder linears the budget
    // actually pages over — the pinned embedding would otherwise dominate)
    let pagable = {
        use splitquant::shardstore::{PagedConfig, PagedModel};
        PagedModel::open(&shards, PagedConfig::default()).unwrap().pagable_bytes()
    };

    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (_, pool) = emotion::load_small(1, 10, 1024);
    let requests = 300usize;
    let mut t = Table::new(
        &format!("S0 — paged vs resident quantized serving ({requests} requests/row)"),
        &["mode", "budget", "QPS", "p50", "p99", "faults", "evictions", "paged in"],
    );
    let mut json: Vec<BenchRecord> = Vec::new();
    let shape = format!("L{}-h{}-{}req", cfg.layers, cfg.hidden, requests);
    for budget_pct in [0usize, 100, 50, 25] {
        let resident = budget_pct == 0;
        let budget = pagable * budget_pct / 100;
        let serve_cfg = ServeConfig {
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_cap: 4096,
            residency_budget_bytes: (!resident).then_some(budget),
            ..ServeConfig::default()
        };
        let exec = if resident {
            Arc::new(QuantExecutor::resident(cfg.clone(), &store, &qm, vec![1, 8]).unwrap())
        } else {
            Arc::new(
                QuantExecutor::paged(cfg.clone(), &shards, vec![1, 8], &serve_cfg).unwrap(),
            )
        };
        let server = Server::start(exec, tok.clone(), serve_cfg);
        let t0 = Instant::now();
        let mut done = 0usize;
        let mut i = 0usize;
        while done < requests {
            let window = 16.min(requests - done);
            let rxs: Vec<_> = (0..window)
                .map(|k| server.submit(&pool.texts[(i + k) % pool.len()]).unwrap())
                .collect();
            i += window;
            for rx in rxs {
                rx.recv_timeout(Duration::from_secs(60)).expect("response").expect("classify");
                done += 1;
            }
        }
        let wall = t0.elapsed();
        let m = server.shutdown();
        let engine =
            if resident { "resident".to_string() } else { format!("paged{budget_pct}") };
        json.push(
            BenchRecord::new("serving-s0", &shape, &engine, wall / requests as u32, {
                // bytes one request streams on average: paged-in shard bytes
                // amortized over the row's requests
                m.bytes_paged_in / requests.max(1)
            })
            .with("qps", requests as f64 / wall.as_secs_f64())
            .with("p50_us", m.latency.quantile_us(0.50) as f64)
            .with("p99_us", m.latency.quantile_us(0.99) as f64)
            .with("plane_decodes", m.plane_decodes as f64)
            .with("plane_reuses", m.plane_reuses as f64),
        );
        t.row(vec![
            if resident { "resident".into() } else { format!("paged {budget_pct}%") },
            if resident { "-".into() } else { format!("{budget}B") },
            format!("{:.0}", requests as f64 / wall.as_secs_f64()),
            format!("{:.1}ms", m.latency.quantile_us(0.50) as f64 / 1e3),
            format!("{:.1}ms", m.latency.quantile_us(0.99) as f64 / 1e3),
            m.shard_faults.to_string(),
            m.shard_evictions.to_string(),
            format!("{}B", m.bytes_paged_in),
        ]);
    }
    std::fs::remove_file(&shards).ok();
    println!("{}", t.render());
    println!("{}", t.render_markdown());
    let path = std::path::Path::new("BENCH_kernels.json");
    match merge_write(path, &json) {
        Ok(()) => println!("[serving] wrote {} records to {}", json.len(), path.display()),
        Err(e) => eprintln!("[serving] could not write {}: {e}", path.display()),
    }
    println!(
        "shape expectation: QPS degrades gracefully as the budget shrinks (faults\n\
         and evictions climb). At 100% nothing evicts (first-touch faults only)\n\
         and the plane cache turns repeat matmuls into reuses (plane_reuses ≫\n\
         plane_decodes in BENCH_kernels.json); under tight budgets evicted\n\
         shards re-decode on re-fault — the CPU price of keeping only packed\n\
         low-bit codes resident.\n"
    );
}

fn main() {
    paged_vs_resident();

    let Ok(rt) = Runtime::new(Path::new("artifacts")) else {
        eprintln!("[serving] SKIP: no artifacts (run `make artifacts`)");
        return;
    };
    let rt = Arc::new(rt);
    let cfg = rt.manifest.bert.clone();
    let store = if Path::new("checkpoints/emotion.bin").exists() {
        ParamStore::load(Path::new("checkpoints/emotion.bin")).unwrap()
    } else {
        ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(7))
    };
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let exec = Arc::new(PjrtExecutor::new(&rt, &store, &[1, 8, 32]).unwrap());
    let (_, pool) = emotion::load_small(1, 10, 1024);

    let requests = 600usize;
    let mut t = Table::new(
        &format!("S1 — serving sweep ({requests} requests/cell)"),
        &["inflight", "workers", "QPS", "p50", "p95", "p99", "pad%", "batch hist"],
    );
    for &workers in &[1usize, 2, 4] {
        for &inflight in &[1usize, 8, 64, 256] {
            let server = Server::start(
                exec.clone(),
                tok.clone(),
                ServeConfig {
                    max_wait: Duration::from_millis(2),
                    workers,
                    queue_cap: 8192,
                    ..ServeConfig::default()
                },
            );
            let t0 = Instant::now();
            let mut done = 0usize;
            let mut i = 0usize;
            while done < requests {
                let window = inflight.min(requests - done);
                let rxs: Vec<_> = (0..window)
                    .map(|k| server.submit(&pool.texts[(i + k) % pool.len()]).unwrap())
                    .collect();
                i += window;
                for rx in rxs {
                    rx.recv_timeout(Duration::from_secs(60))
                        .expect("response")
                        .expect("classify");
                    done += 1;
                }
            }
            let wall = t0.elapsed();
            let m = server.shutdown();
            t.row(vec![
                inflight.to_string(),
                workers.to_string(),
                format!("{:.0}", requests as f64 / wall.as_secs_f64()),
                format!("{:.1}ms", m.latency.quantile_us(0.50) as f64 / 1e3),
                format!("{:.1}ms", m.latency.quantile_us(0.95) as f64 / 1e3),
                format!("{:.1}ms", m.latency.quantile_us(0.99) as f64 / 1e3),
                format!("{:.0}%", m.padding_fraction() * 100.0),
                format!("{:?}", m.batches_by_size),
            ]);
        }
    }
    println!("{}", t.render());
    println!("{}", t.render_markdown());
    println!(
        "shape expectation: QPS rises with inflight (batching amortizes dispatch);\n\
         p50 rises with batch occupancy; padding% falls as load saturates b32.\n"
    );

    // ---- open-loop trace replay with admission control
    use splitquant::data::trace::{generate, summarize, TraceKind};
    use splitquant::util::rng::Rng as SqRng;
    let mut t2 = Table::new(
        "S1b — open-loop trace replay (2000 arrivals, admission control on)",
        &["trace", "offered rate", "served", "shed", "QPS", "p50", "p99"],
    );
    let mut rng = SqRng::new(0);
    for (name, kind) in [
        ("poisson@200/s", TraceKind::Poisson { rate: 200.0 }),
        ("poisson@2000/s", TraceKind::Poisson { rate: 2000.0 }),
        (
            "bursty 50/3000",
            TraceKind::Bursty { calm_rate: 50.0, burst_rate: 3000.0, mean_phase_s: 0.3 },
        ),
    ] {
        let arrivals = generate(kind, 2000, pool.len(), &mut rng);
        let (mean_rate, _) = summarize(&arrivals);
        let server = Server::start(
            exec.clone(),
            tok.clone(),
            ServeConfig {
                max_wait: Duration::from_millis(2),
                workers: 2,
                queue_cap: 256,
                ..ServeConfig::default()
            },
        );
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        let mut shed = 0usize;
        for a in &arrivals {
            // busy-ish wait to the arrival time (trace replay)
            while t0.elapsed() < a.at {
                std::thread::sleep(Duration::from_micros(100));
            }
            match server.try_submit(&pool.texts[a.text_id]) {
                Ok(rx) => rxs.push(rx),
                Err(_) => shed += 1,
            }
        }
        let mut served = 0usize;
        for rx in rxs {
            if rx.recv_timeout(Duration::from_secs(60)).is_ok_and(|r| r.is_ok()) {
                served += 1;
            }
        }
        let wall = t0.elapsed();
        let m = server.shutdown();
        t2.row(vec![
            name.to_string(),
            format!("{mean_rate:.0}/s"),
            served.to_string(),
            shed.to_string(),
            format!("{:.0}", served as f64 / wall.as_secs_f64()),
            format!("{:.1}ms", m.latency.quantile_us(0.50) as f64 / 1e3),
            format!("{:.1}ms", m.latency.quantile_us(0.99) as f64 / 1e3),
        ]);
    }
    println!("{}", t2.render());
    println!("{}", t2.render_markdown());
}
