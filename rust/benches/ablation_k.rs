//! Bench A1 (ablation): cluster count k ∈ {1..5} at INT2 on the emotion
//! checkpoint. k=1 degenerates to per-tensor quantization (with zero-extended
//! range); the paper fixes k=3 — this bench justifies that choice.
//!
//! ```sh
//! cargo bench --bench ablation_k
//! ```

use std::path::Path;
use std::time::Instant;

use splitquant::data::{emotion, pad_to_batches, HashTokenizer};
use splitquant::eval::{accuracy_rust, prepare_store, WeightMethod};
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::report::{pct, Table};
use splitquant::splitquant::SplitQuantConfig;
use splitquant::util::rng::Rng;

fn main() {
    let cfg = BertConfig::default();
    let store = if Path::new("checkpoints/emotion.bin").exists() {
        ParamStore::load(Path::new("checkpoints/emotion.bin")).unwrap()
    } else {
        eprintln!("[ablation_k] no checkpoint; using random init (accuracy ≈ chance)");
        ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(0))
    };
    let (_, test) = emotion::load(0);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, n) = pad_to_batches(&test, &tok, 32);
    let fp32 = accuracy_rust(&cfg, &store, &batches, n, None).unwrap();

    let quantizable = splitquant::splitquant::default_quantizable(&store);
    let mut t = Table::new(
        &format!("A1 — emotion INT2 accuracy vs cluster count k (FP32 {})", pct(fp32)),
        &["k", "accuracy", "recon MSE", "transform time", "cid bits"],
    );
    for k in 1..=5usize {
        let sq = SplitQuantConfig::new(2).with_k(k);
        let t0 = Instant::now();
        let (eval_store, _) = prepare_store(&store, &WeightMethod::SplitQuant(sq)).unwrap();
        let transform = t0.elapsed();
        let acc = accuracy_rust(&cfg, &eval_store, &batches, n, None).unwrap();
        let mse: f64 = quantizable
            .iter()
            .map(|name| {
                let o = store.get(name).unwrap();
                let q = eval_store.get(name).unwrap();
                o.data()
                    .iter()
                    .zip(q.data())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
            })
            .sum();
        t.row(vec![
            k.to_string(),
            pct(acc),
            format!("{mse:.3}"),
            format!("{transform:.2?}"),
            splitquant::splitquant::weight_split::cid_bits(k).to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("{}", t.render_markdown());
    println!(
        "shape expectation: accuracy jumps from k=1 to k=2-3, then saturates —\n\
         the paper's k=3 (lower/middle/upper) sits at the knee; cost grows with k."
    );
}
