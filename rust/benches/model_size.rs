//! Bench D1: the paper's **§6 model-size claims** — INT2 = 6.25 % of FP32,
//! SplitQuant "up to 18.75 %" if the three split layers are materialized
//! densely, far less with the fused codes+cid form or sparse storage.
//!
//! ```sh
//! cargo bench --bench model_size
//! ```

use std::path::Path;

use splitquant::baselines;
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::model::sparse::SparseSplitLinear;
use splitquant::quant::QConfig;
use splitquant::report::{bytes, Table};
use splitquant::splitquant::weight_split::materialize_branches;
use splitquant::splitquant as sq;
use splitquant::splitquant::SplitQuantConfig;
use splitquant::util::rng::Rng;

fn main() {
    // use the trained checkpoint when available for realistic value stats
    let cfg = BertConfig::default();
    let store = if Path::new("checkpoints/emotion.bin").exists() {
        ParamStore::load(Path::new("checkpoints/emotion.bin")).unwrap()
    } else {
        eprintln!("[model_size] no checkpoint; using random init");
        ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(0))
    };
    let quantizable = sq::default_quantizable(&store);
    let fp32_bytes: usize =
        quantizable.iter().map(|n| store.get(n).unwrap().byte_size()).sum();

    let mut t = Table::new(
        &format!(
            "§6 model size — quantizable params {} ({} tensors)",
            bytes(fp32_bytes),
            quantizable.len()
        ),
        &["representation", "bytes", "% of FP32", "paper arithmetic"],
    );
    t.row(vec!["FP32".into(), bytes(fp32_bytes), "100%".into(), "100%".into()]);

    for bits in [2u8, 4, 8] {
        let (_, base) = baselines::quantize_store_baseline(
            &store,
            &quantizable,
            &QConfig::baseline(bits),
        )
        .unwrap();
        let b = baselines::quantized_bytes(&base);
        t.row(vec![
            format!("baseline INT{bits} (packed)"),
            bytes(b),
            format!("{:.2}%", 100.0 * b as f64 / fp32_bytes as f64),
            format!("{:.2}%", 100.0 * bits as f64 / 32.0),
        ]);

        let (_, sq) =
            sq::quantize_store(&store, &quantizable, &SplitQuantConfig::new(bits))
                .unwrap();
        let sqb = sq.quantized_bytes();
        t.row(vec![
            format!("SplitQuant INT{bits} fused codes+cid (ours)"),
            bytes(sqb),
            format!("{:.2}%", 100.0 * sqb as f64 / fp32_bytes as f64),
            "-".into(),
        ]);

        // the paper's dense-materialization upper bound: 3 layers of codes
        let dense3 = 3 * b;
        t.row(vec![
            format!("SplitQuant INT{bits} 3 dense layers (paper bound)"),
            bytes(dense3),
            format!("{:.2}%", 100.0 * dense3 as f64 / fp32_bytes as f64),
            format!("{:.2}%", 3.0 * 100.0 * bits as f64 / 32.0),
        ]);
    }
    println!("{}", t.render());
    println!("{}", t.render_markdown());

    // ---- sparse recovery (the SparseDNN remark): one representative layer
    let name = "encoder.0.ffn.in.weight";
    let w = store.get(name).unwrap();
    let mut rng = Rng::new(1);
    let st = sq::split_quantize(w, &SplitQuantConfig::new(2), &mut rng).unwrap();
    let branches = materialize_branches(w, &st.assignment, 3);
    let sp = SparseSplitLinear::from_dense_branches(&branches, None);
    let mut s = Table::new(
        &format!("sparse storage of the split {name} ({}, FP32)", bytes(w.byte_size())),
        &["form", "bytes", "vs FP32 layer"],
    );
    s.row(vec!["3 dense FP32 branches".into(), bytes(3 * w.byte_size()), "300%".into()]);
    s.row(vec![
        "3 CSR branches (u32 idx)".into(),
        bytes(sp.byte_size()),
        format!("{:.0}%", 100.0 * sp.byte_size() as f64 / w.byte_size() as f64),
    ]);
    s.row(vec![
        "fused INT2 codes + 2-bit cid".into(),
        bytes(st.qtensor.byte_size()),
        format!("{:.1}%", 100.0 * st.qtensor.byte_size() as f64 / w.byte_size() as f64),
    ]);
    println!("{}", s.render());
    println!(
        "shape expectation: packed INT2 ≈ 6.25% + scale metadata; fused SplitQuant adds\n\
         only the cid plane (INT2: +6.25%, total ≈ 12.5%) — under the paper's 18.75% bound."
    );

    // ---- serving replicas: share() views are O(1), COW only on write
    let n_replicas = 8usize;
    let replicas: Vec<ParamStore> = (0..n_replicas).map(|_| store.share()).collect();
    let mut views: Vec<&ParamStore> = vec![&store];
    views.extend(replicas.iter());
    let resident = ParamStore::resident_bytes(views);
    let naive = (n_replicas + 1) * store.byte_size();
    let mut r = Table::new(
        &format!("{n_replicas} serving replicas from one ParamStore::share()"),
        &["form", "resident bytes", "vs 1 copy"],
    );
    r.row(vec![
        "deep clone per replica (old)".into(),
        bytes(naive),
        format!("{:.0}%", 100.0 * naive as f64 / store.byte_size() as f64),
    ]);
    r.row(vec![
        "Arc-shared copy-on-write (ours)".into(),
        bytes(resident),
        format!("{:.0}%", 100.0 * resident as f64 / store.byte_size() as f64),
    ]);
    println!("{}", r.render());
    assert_eq!(resident, store.byte_size(), "replicas must not duplicate weights");

    // ---- mixed precision: where does a between-uniform-widths budget land?
    // (the ISSUE-5 autotuner; sensitivity from a small calibration slice)
    use splitquant::autotune::{allocate, sweep, SweepConfig};
    use splitquant::data::{emotion, pad_to_batches, HashTokenizer};
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (calib_set, _) = emotion::load_small(0, 10, 32);
    let (calib, _) = pad_to_batches(&calib_set, &tok, 16);
    let table = sweep(&cfg, &store, &calib[..1], &SweepConfig::default()).unwrap();
    let mut a = Table::new(
        "autotuned BitPlan bytes between the uniform widths (budget = uniform INT4)",
        &["assignment", "packed bytes", "% of FP32"],
    );
    for bits in [2u8, 4, 8] {
        let ub = table.uniform_bytes(bits).unwrap();
        a.row(vec![
            format!("uniform INT{bits}"),
            bytes(ub),
            format!("{:.2}%", 100.0 * ub as f64 / fp32_bytes as f64),
        ]);
    }
    let budget = table.uniform_bytes(4).unwrap();
    let plan = allocate(&table, budget).unwrap();
    a.row(vec![
        format!("BitPlan {}", plan.summary()),
        bytes(plan.planned_bytes),
        format!("{:.2}%", 100.0 * plan.planned_bytes as f64 / fp32_bytes as f64),
    ]);
    println!("{}", a.render());
    assert!(plan.planned_bytes <= budget, "plan must respect the byte budget");
}
