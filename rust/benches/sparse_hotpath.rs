//! Bench D2: the paper's **§6 sparse-engine remark** — split layers are ~⅔
//! structural zeros, so a sparse engine (SparseDNN-style; ours is CSR)
//! recovers most of the 3× dense overhead. Measures the BERT-Tiny linear
//! shapes end to end.
//!
//! ```sh
//! cargo bench --bench sparse_hotpath
//! ```

use std::time::Instant;

use splitquant::model::graph::{Layer, LinearPart};
use splitquant::model::sparse::SparseSplitLinear;
use splitquant::report::Table;
use splitquant::splitquant::weight_split::materialize_branches;
use splitquant::splitquant::{split_quantize, SplitQuantConfig};
use splitquant::tensor::{ops, Tensor};
use splitquant::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let reps = 300usize;
    let mut t = Table::new(
        &format!("D2 — split-layer execution forms ({reps} reps, batch 64)"),
        &["shape", "dense 1x", "3 dense branches", "CSR split", "fused dequant", "CSR vs 3x"],
    );

    for &(k, n) in &[(128usize, 128usize), (128, 512), (512, 128)] {
        let w = Tensor::randn(&[k, n], 0.0, 0.5, &mut rng);
        let x = Tensor::randn(&[64, k], 0.0, 1.0, &mut rng);
        let st = split_quantize(&w, &SplitQuantConfig::new(2), &mut rng).unwrap();
        let branches = materialize_branches(&w, &st.assignment, 3);

        let dense = Layer::Linear { weight: w.clone(), bias: None };
        let split3 = Layer::SplitLinear {
            parts: branches
                .iter()
                .map(|b| LinearPart { weight: b.clone(), bias: None })
                .collect(),
        };
        let csr = SparseSplitLinear::from_dense_branches(&branches, None);
        let fused = st.qtensor.dequantize();

        let time = |f: &dyn Fn() -> Tensor| {
            let t0 = Instant::now();
            for _ in 0..reps {
                std::hint::black_box(f());
            }
            t0.elapsed()
        };
        let t_dense = time(&|| dense.forward(&x));
        let t_split = time(&|| split3.forward(&x));
        let t_csr = time(&|| csr.forward(&x));
        let t_fused = time(&|| ops::matmul(&x, &fused));

        t.row(vec![
            format!("{k}x{n}"),
            format!("{t_dense:.2?}"),
            format!("{t_split:.2?}"),
            format!("{t_csr:.2?}"),
            format!("{t_fused:.2?}"),
            format!("{:.2}x faster", t_split.as_secs_f64() / t_csr.as_secs_f64()),
        ]);
    }
    println!("{}", t.render());
    println!("{}", t.render_markdown());
    println!(
        "measured shape: 3-branch ≈ 2.5-3x dense (the paper's §6 overhead). CSR\n\
         keeps nnz at 1x but LOSES wall-clock at ~33% density — indirect column\n\
         indices defeat vectorization, the textbook spmm break-even is ~5-10%\n\
         density, and SplitQuant branches sit far above it. This is exactly why\n\
         the deployment path is the FUSED codes+cid matmul (≈1x dense, zeros\n\
         never materialized) rather than a generic sparse engine; an engine with\n\
         structured sparsity (SparseDNN-style codegen) would be needed to win at\n\
         this density. Storage, not speed, is what CSR recovers here."
    );
}
