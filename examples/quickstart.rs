//! Quickstart: SplitQuant on a single layer and on a whole model, no
//! artifacts required (pure-Rust path).
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the paper's §1 dilemma and §4 resolution:
//! 1. an outlier destroys INT2 resolution under min-max quantization,
//! 2. percentile clipping rescues the bulk but destroys the outlier,
//! 3. SplitQuant keeps both.

use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::quant::pipeline::{BaselinePass, BnFold, QuantPipeline, SplitQuantPass};
use splitquant::quant::{QConfig, QParams, QTensor};
use splitquant::report::{pct, Table};
use splitquant::splitquant as sq;
use splitquant::tensor::Tensor;
use splitquant::util::rng::Rng;

fn mse(a: &Tensor, b: &Tensor) -> f64 {
    a.data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.numel() as f64
}

fn main() -> splitquant::Result<()> {
    println!("== 1. The outlier dilemma (paper §1) ==\n");
    let mut rng = Rng::new(42);
    let mut values: Vec<f32> = (0..4095).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    values.push(500.0); // one strong signal
    let t = Tensor::new(&[4096], values)?;

    let bits = 2;
    // (a) keep the outlier: min-max INT2
    let minmax = QTensor::quantize(&t, &QConfig::baseline(bits))?.dequantize();
    // (b) clip the outlier: 99th-percentile INT2
    let clipped = QTensor::quantize(&t, &QConfig::percentile(bits, 99.0))?.dequantize();
    // (c) SplitQuant: cluster, split, per-cluster scales
    let mut sq_rng = Rng::new(0);
    let split = sq::split_quantize(&t, &sq::SplitQuantConfig::new(bits), &mut sq_rng)?;
    let sqt = split.qtensor.dequantize();

    let bulk_mse = |x: &Tensor| -> f64 {
        x.data()
            .iter()
            .zip(t.data())
            .take(4095)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / 4095.0
    };
    let outlier_err = |x: &Tensor| (x.data()[4095] - 500.0).abs();

    let mut tab = Table::new(
        "INT2 on N(0,1) + one outlier at 500",
        &["method", "bulk MSE", "outlier |err|"],
    );
    tab.row(vec![
        "min-max (keep)".into(),
        format!("{:.4}", bulk_mse(&minmax)),
        format!("{:.1}", outlier_err(&minmax)),
    ]);
    tab.row(vec![
        "pct99 (clip)".into(),
        format!("{:.4}", bulk_mse(&clipped)),
        format!("{:.1}", outlier_err(&clipped)),
    ]);
    tab.row(vec![
        "SplitQuant".into(),
        format!("{:.4}", bulk_mse(&sqt)),
        format!("{:.1}", outlier_err(&sqt)),
    ]);
    println!("{}", tab.render());
    println!("cluster centroids (lower/middle/upper): {:?}", split.centroids);
    println!(
        "per-cluster quantization steps: {:?}\n",
        split.qtensor.params().iter().map(QParams::step).collect::<Vec<_>>()
    );

    println!("== 2. Whole-model PTQ (pure-Rust executor) ==\n");
    // a small randomly-initialized BERT: quantization *reconstruction* is
    // meaningful even untrained (for accuracy-level results see
    // examples/train_and_quantize.rs)
    let cfg = BertConfig {
        vocab_size: 2048,
        hidden: 64,
        layers: 2,
        heads: 2,
        ffn: 128,
        max_len: 32,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let mut rng = Rng::new(1);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = sq::default_quantizable(&store);
    println!(
        "model: {} params in {} tensors ({} quantizable)",
        store.numel(),
        store.len(),
        quantizable.len()
    );

    // every PTQ method is a pass over one shared ModelArtifact: the pipeline
    // never deep-copies the model — eval views share untouched tensors with
    // `store` (copy-on-write), so a sweep over bit-widths is cheap
    let mut tab = Table::new(
        "weight reconstruction MSE across the model",
        &["bits", "baseline (min-max)", "SplitQuant", "improvement"],
    );
    for bits in [2u8, 4, 8] {
        let base = QuantPipeline::new()
            .pass(BaselinePass::new(QConfig::baseline(bits)))
            .run(&store)?;
        let split = QuantPipeline::new()
            .pass(BnFold) // §4.1 fold (a no-op on BERT; shown for the shape of the API)
            .pass(SplitQuantPass::bits(bits))
            .run(&store)?;
        let m_base: f64 = quantizable
            .iter()
            .map(|n| mse(store.get(n).unwrap(), base.eval.get(n).unwrap()))
            .sum();
        let m_sq: f64 = quantizable
            .iter()
            .map(|n| mse(store.get(n).unwrap(), split.eval.get(n).unwrap()))
            .sum();
        tab.row(vec![
            format!("INT{bits}"),
            format!("{m_base:.3e}"),
            format!("{m_sq:.3e}"),
            pct(1.0 - m_sq / m_base),
        ]);
    }
    println!("{}", tab.render());

    println!("== 3. Mixed precision per layer ==\n");
    // per-layer overrides: keep the classifier head at INT8 while the body
    // drops to INT2 — one pass, one artifact, provenance recorded
    let mixed = QuantPipeline::new()
        .pass(SplitQuantPass::bits(2).layer_bits("classifier.weight", 8))
        .run(&store)?;
    println!(
        "applied passes: {:?}\nclassifier.weight bits: {}  encoder body bits: {}",
        mixed.provenance,
        mixed.tensors["classifier.weight"].bits(),
        mixed.tensors["encoder.0.attn.q.weight"].bits(),
    );
    println!(
        "eval view shares untouched tensors with the source store: ln.gamma shared = {}\n",
        mixed.eval.shares_tensor(&store, "embeddings.ln.gamma")
    );
    println!(
        "next: cargo run --release --example train_and_quantize  (full Table 1 on trained models)"
    );
    Ok(())
}
