//! Serving demo: batched classification through the coordinator + PJRT
//! executables, reporting latency percentiles and throughput.
//!
//! ```sh
//! cargo run --release --example serve -- [requests] [workers] [ckpt] [kernel] \
//!     [--trace <path>] [--metrics-json] [--bench-json[=<path>]] \
//!     [--qhealth] [--shadow-rate <n>]
//! ```
//!
//! `--trace <path>` enables the process-wide trace recorder
//! (`splitquant::trace`) and writes a Chrome trace-event JSON file —
//! load it at `ui.perfetto.dev`. `--metrics-json` prints the
//! deterministic sorted-key metrics JSON for each mode after serving.
//! `--bench-json` merges each mode's latency-breakdown rows into
//! `BENCH_serving.json` (or the `=`-given path) keyed by
//! `(bench, shape, engine)`, replacing rows in place on re-runs.
//! Without compiled PJRT artifacts the demo falls back to the pure-Rust
//! executor on a small random model, so all flags work anywhere.
//!
//! `--qhealth` arms the numeric-health switch (`splitquant::qhealth`) and
//! `--shadow-rate <n>` routes 1-in-n requests through the shadow-sampling
//! hook; this demo serves FP32 weights, whose executors expose no
//! quantization signals, so the telemetry printed per mode carries the
//! always-on `splitquant_quant_drift 0` gauge and no per-layer families —
//! see `serve_paged` for the quantized path the monitors exist for.
//!
//! `kernel` picks the micro-kernel family (`scalar` | `simd` | `int8`,
//! default: `simd` when compiled in) via `ServeConfig::parallel.kernel` —
//! the PR-4 engine knob, extended in PR-6 with the integer datapath. The
//! `scalar`/`simd` engines are bit-identical, so they only move the
//! latency/throughput numbers; `int8` additionally quantizes activations
//! on the fused quantized path (this demo serves FP32 weights through
//! PJRT, where `int8` rides the f32 kernels — see `serve_paged` for the
//! engine on packed weights). The PR-3 paging knob
//! (`ServeConfig::residency_budget_bytes`) stays `None` here — this demo
//! serves FP32 weights through PJRT; see `examples/serve_paged.rs` for a
//! quantized model served under a residency byte budget.
//!
//! Uses `checkpoints/emotion.bin` when present (train one with the
//! `train_and_quantize` example), otherwise serves a randomly initialized
//! model — the serving path is identical either way.
//!
//! ## Batching semantics
//!
//! The batcher sleeps on a Condvar (zero idle CPU; see
//! `Metrics::batcher_polls`) and wakes the instant a request is enqueued.
//! A full batch (pending ≥ largest compiled size) dispatches immediately;
//! otherwise dispatch happens when the oldest request has waited
//! `max_wait`, padded to the smallest compiled size that fits — capped at
//! `batcher::MAX_PADDING_OVERHEAD` (2×) waste. Above the cap the batcher
//! sends a zero-padding sub-batch of the largest compiled size that the
//! pending requests fill completely and leaves the rest queued: 9 pending
//! against sizes [1, 8, 32] runs the b8 executable once, not a b32 that is
//! 72% padding.
//!
//! ## Kernel parallelism
//!
//! `ServeConfig::parallel` is a `splitquant::parallel::ParallelConfig`
//! { threads, tile_k, tile_n, serial_flops }: one process-wide worker pool
//! shared by every serving worker (workers overlap dispatches, they do not
//! multiply kernel threads). `threads: 0` resolves SPLITQUANT_THREADS or
//! the machine's core count; small matmuls (< serial_flops FLOPs, e.g. the
//! b1 latency path) stay on the calling thread.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use splitquant::coordinator::{BatchExecutor, PjrtExecutor, RustExecutor, ServeConfig, Server};
use splitquant::data::{emotion, HashTokenizer};
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::parallel::{KernelKind, ParallelConfig};
use splitquant::report::Table;
use splitquant::runtime::Runtime;
use splitquant::util::rng::Rng;

fn main() -> splitquant::Result<()> {
    let mut trace_path: Option<String> = None;
    let mut metrics_json = false;
    let mut bench_json: Option<String> = None;
    let mut qhealth_on = false;
    let mut shadow_rate: u64 = 8;
    let mut args: Vec<String> = Vec::new();
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        match a.as_str() {
            "--trace" => {
                trace_path = Some(argv.next().ok_or_else(|| {
                    splitquant::Error::Coordinator("--trace needs an output path".into())
                })?);
            }
            "--metrics-json" => metrics_json = true,
            "--bench-json" => bench_json = Some("BENCH_serving.json".to_string()),
            "--qhealth" => qhealth_on = true,
            "--shadow-rate" => {
                shadow_rate = argv.next().and_then(|s| s.parse().ok()).ok_or_else(|| {
                    splitquant::Error::Coordinator("--shadow-rate needs an integer".into())
                })?;
            }
            _ => match a.strip_prefix("--bench-json=") {
                Some(p) => bench_json = Some(p.to_string()),
                None => args.push(a),
            },
        }
    }
    if trace_path.is_some() {
        splitquant::trace::set_enabled(true);
    }
    if qhealth_on {
        splitquant::qhealth::set_enabled(true);
    }
    let requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(1000);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let ckpt = args.get(2).cloned().unwrap_or_else(|| "checkpoints/emotion.bin".to_string());
    let kernel = match args.get(3) {
        None => KernelKind::default(),
        Some(s) => KernelKind::from_flag(s).ok_or_else(|| {
            splitquant::Error::Coordinator(format!(
                "unknown kernel {s:?} (valid engines: scalar|simd|int8)"
            ))
        })?,
    };
    println!(
        "[serve] kernel engine: {kernel:?} (effective {:?}); residency budget: unbounded \
         (FP32/PJRT path — see serve_paged for the paging knob)",
        kernel.effective()
    );

    let (exec, cfg): (Arc<dyn BatchExecutor>, BertConfig) = if Path::new("artifacts").exists()
    {
        let rt = Arc::new(Runtime::new(Path::new("artifacts"))?);
        let cfg = rt.manifest.bert.clone();
        let store = if Path::new(&ckpt).exists() {
            println!("[serve] loading checkpoint {ckpt}");
            ParamStore::load(Path::new(&ckpt))?
        } else {
            println!("[serve] no checkpoint at {ckpt}; serving random weights");
            ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(7))
        };
        // compile b1/b8/b32 forward executables up front; PjrtExecutor
        // stages the parameter literals once per executable — requests
        // borrow them, so serving N workers never re-materializes weights
        let t0 = Instant::now();
        let exec = Arc::new(PjrtExecutor::new(&rt, &store, &[1, 8, 32])?);
        println!("[serve] compiled {} executables in {:?}", rt.compiled_count(), t0.elapsed());
        (exec, cfg)
    } else {
        // no compiled artifacts: serve the same traffic through the
        // pure-Rust executor on a small random model, so the demo (and
        // the CI trace-smoke lane) runs without the Python build step
        println!("[serve] no artifacts/ directory; pure-Rust executor on random weights");
        let cfg = BertConfig {
            vocab_size: 2048,
            hidden: 32,
            layers: 2,
            heads: 2,
            ffn: 64,
            max_len: 32,
            num_classes: 6,
            ln_eps: 1e-12,
        };
        let store = ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(7));
        let exec = Arc::new(RustExecutor::new(cfg.clone(), store, vec![1, 8, 32])?);
        (exec, cfg)
    };
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);

    let (_, requests_pool) = emotion::load_small(1, 10, 2048);

    let mut report = Table::new(
        "serving: latency/throughput vs offered concurrency",
        &["mode", "requests", "wall", "QPS", "p50", "p95", "p99", "pad%", "batches"],
    );

    // ---- closed-loop (one at a time): latency floor, batch size 1
    for (mode, inflight) in [("closed-loop", 1usize), ("burst", 256)] {
        let server = Server::start(
            exec.clone(),
            tok.clone(),
            // auto thread count; set `parallel.threads` explicitly to pin
            // the kernel pool size. `parallel.kernel` is the CLI's engine
            // choice (process-wide: the first Server::start wins)
            ServeConfig {
                max_wait: Duration::from_millis(2),
                workers,
                queue_cap: 8192,
                parallel: ParallelConfig { kernel, ..ParallelConfig::default() },
                residency_budget_bytes: None,
                shadow: qhealth_on
                    .then_some(splitquant::qhealth::ShadowConfig { seed: 7, rate: shadow_rate }),
                ..ServeConfig::default()
            },
        );
        let t0 = Instant::now();
        let mut done = 0usize;
        let mut i = 0usize;
        while done < requests {
            let window = inflight.min(requests - done);
            let rxs: Vec<_> = (0..window)
                .map(|k| {
                    let text = &requests_pool.texts[(i + k) % requests_pool.len()];
                    server.submit(text)
                })
                .collect::<splitquant::Result<Vec<_>>>()?;
            i += window;
            for rx in rxs {
                rx.recv_timeout(Duration::from_secs(30))
                    .map_err(|_| splitquant::Error::Coordinator("timeout".into()))??;
                done += 1;
            }
        }
        let wall = t0.elapsed();
        if qhealth_on {
            println!("[serve] telemetry[{mode}]:\n{}", server.telemetry_text());
        }
        let m = server.shutdown();
        if metrics_json {
            println!("[serve] metrics[{mode}] = {}", m.to_json().to_string());
        }
        if let Some(path) = &bench_json {
            let engine = format!("{:?}", kernel.effective()).to_lowercase();
            let rows = m.breakdown_records(mode, &engine);
            splitquant::report::bench_json::merge_write(Path::new(path), &rows)?;
            println!("[serve] merged {} breakdown rows into {path}", rows.len());
        }
        report.row(vec![
            mode.to_string(),
            requests.to_string(),
            format!("{wall:.2?}"),
            format!("{:.0}", requests as f64 / wall.as_secs_f64()),
            format!("{:.1}ms", m.latency.quantile_us(0.50) as f64 / 1000.0),
            format!("{:.1}ms", m.latency.quantile_us(0.95) as f64 / 1000.0),
            format!("{:.1}ms", m.latency.quantile_us(0.99) as f64 / 1000.0),
            format!("{:.0}%", m.padding_fraction() * 100.0),
            format!("{:?}", m.batches_by_size),
        ]);
    }
    println!("\n{}", report.render());
    println!("(markdown)\n{}", report.render_markdown());
    if let Some(path) = trace_path {
        let snap = splitquant::trace::snapshot();
        splitquant::trace::chrome::write_chrome_trace(Path::new(&path), &snap)?;
        println!(
            "[serve] wrote {} trace events ({} dropped) to {path}",
            snap.total_events(),
            snap.dropped
        );
    }
    Ok(())
}
