//! Shard-paged serving demo: a quantized model served under a residency
//! budget **smaller than its packed payload** — the "model larger than
//! RAM" scenario, scaled down so it runs anywhere in seconds.
//!
//! ```sh
//! cargo run --release --example serve_paged -- [requests] [budget_pct] [kernel] \
//!     [--trace <path>] [--metrics-json] [--bench-json[=<path>]] \
//!     [--qhealth] [--shadow-rate <n>] \
//!     [--fault-seed <n>] [--fault-rate <p>] [--retry-max <n>]
//! ```
//!
//! `--trace <path>` enables the process-wide trace recorder
//! (`splitquant::trace`) and writes a Chrome trace-event JSON file with
//! the request-lifecycle spans, shard fault/eviction events and kernel
//! chunk spans of both modes — load it at `ui.perfetto.dev`.
//! `--metrics-json` prints each mode's deterministic metrics JSON.
//! `--bench-json` merges each mode's latency-breakdown rows
//! (`breakdown-total/queue/batch/exec/fault`) into `BENCH_serving.json`
//! (or the `=`-given path) keyed by `(bench, shape, engine)` — re-running
//! replaces rows in place, it never duplicates them.
//!
//! `kernel` (`scalar` | `simd` | `int8`, default `simd` when compiled in)
//! picks the micro-kernel family via `ServeConfig::parallel.kernel` — both
//! modes below run the chosen engine, and the logit agreement assertion
//! holds for every engine: `scalar`/`simd` are bit-identical f32 paths, and
//! `int8` (the PR-6 integer datapath: activations quantized per call, raw
//! packed codes consumed by an i8×i8→i32 kernel) is deterministic, so the
//! resident and paged modes still agree label-for-label on it.
//!
//! No artifacts needed (pure-Rust fused executor). The demo quantizes a
//! random BERT-Tiny with SplitQuant INT2, writes the sharded `SQSH0001`
//! file, then serves the same traffic twice:
//!
//! * **resident** — every fused linear unpacked in RAM (the PR-2 path),
//! * **paged** — packed shards fault in on demand under
//!   `ServeConfig::residency_budget_bytes` (default 35 % of the pagable
//!   encoder weights), LRU-evicting over the encoder layers while
//!   embeddings/LayerNorm stay pinned; sequential prefetch follows the
//!   layer execution order.
//!
//! Labels agree between the two modes (the paged path runs the identical
//! fused kernel on identical planes — logits are byte-identical), while
//! the metrics show the paging traffic and the bounded working set.
//!
//! `--qhealth` arms the numeric-health monitors (`splitquant::qhealth`) on
//! both modes: activation-drift clip fractions, per-layer cluster
//! occupancy, outlier-hatch hit rates, and — at 1-in-`--shadow-rate`
//! requests (seeded, deterministic, default 8; 0 disables) — a shadow
//! replay through the FP32 reference engine measuring logit KL and top-1
//! agreement. Each mode prints its Prometheus telemetry (including the
//! `splitquant_quant_drift` gauge) and the sorted `doctor`-style report;
//! with `--bench-json` the per-layer `qhealth-<layer>` rows merge into the
//! same benchmark file. Without the flag the monitors stay disarmed: the
//! hot path keeps its zero-overhead contract and logits are bit-identical.
//!
//! `--fault-rate <p>` (with optional `--fault-seed <n>`, default 1) turns on
//! deterministic fault injection on the paged mode's shard reads — IO
//! errors, short reads and bit flips, each at probability `p` per read.
//! `--retry-max <n>` bounds the read retries (default 3). Under injection
//! the demo demonstrates graceful degradation instead of total agreement:
//! surviving requests still match the resident labels exactly, degraded
//! requests error cleanly, and the chaos counters land in the metrics.

use std::sync::Arc;
use std::time::{Duration, Instant};

use splitquant::coordinator::{QuantExecutor, ServeConfig, Server};
use splitquant::data::{emotion, HashTokenizer};
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::parallel::{KernelKind, ParallelConfig};
use splitquant::quant::PackedModel;
use splitquant::report::Table;
use splitquant::shardstore::{FaultConfig, RetryPolicy};
use splitquant::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
use splitquant::util::rng::Rng;

fn main() -> splitquant::Result<()> {
    let mut trace_path: Option<String> = None;
    let mut metrics_json = false;
    let mut bench_json: Option<String> = None;
    let mut fault_seed: u64 = 1;
    let mut fault_rate: f64 = 0.0;
    let mut retry_max: u32 = RetryPolicy::default().max_attempts;
    let mut qhealth_on = false;
    let mut shadow_rate: u64 = 8;
    let mut args: Vec<String> = Vec::new();
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        if a == "--trace" {
            trace_path = Some(argv.next().ok_or_else(|| {
                splitquant::Error::Coordinator("--trace needs an output path".into())
            })?);
        } else if a == "--fault-seed" {
            fault_seed = argv.next().and_then(|s| s.parse().ok()).ok_or_else(|| {
                splitquant::Error::Coordinator("--fault-seed needs an integer".into())
            })?;
        } else if a == "--fault-rate" {
            fault_rate = argv.next().and_then(|s| s.parse().ok()).ok_or_else(|| {
                splitquant::Error::Coordinator("--fault-rate needs a probability".into())
            })?;
        } else if a == "--retry-max" {
            retry_max = argv.next().and_then(|s| s.parse().ok()).ok_or_else(|| {
                splitquant::Error::Coordinator("--retry-max needs an integer".into())
            })?;
        } else if a == "--metrics-json" {
            metrics_json = true;
        } else if a == "--qhealth" {
            qhealth_on = true;
        } else if a == "--shadow-rate" {
            shadow_rate = argv.next().and_then(|s| s.parse().ok()).ok_or_else(|| {
                splitquant::Error::Coordinator("--shadow-rate needs an integer".into())
            })?;
        } else if a == "--bench-json" {
            bench_json = Some("BENCH_serving.json".to_string());
        } else if let Some(p) = a.strip_prefix("--bench-json=") {
            bench_json = Some(p.to_string());
        } else {
            args.push(a);
        }
    }
    if trace_path.is_some() {
        splitquant::trace::set_enabled(true);
    }
    if qhealth_on {
        splitquant::qhealth::set_enabled(true);
    }
    let requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(400);
    let budget_pct: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(35);
    let kernel = match args.get(2) {
        None => KernelKind::default(),
        Some(s) => KernelKind::from_flag(s).ok_or_else(|| {
            splitquant::Error::Coordinator(format!(
                "unknown kernel {s:?} (valid engines: scalar|simd|int8)"
            ))
        })?,
    };
    println!("[serve_paged] kernel engine: {kernel:?} (effective {:?})", kernel.effective());
    let faults_on = fault_rate > 0.0;
    if faults_on {
        println!(
            "[serve_paged] fault injection on the paged mode: seed {fault_seed}, \
             rate {fault_rate} per kind per read, retry budget {retry_max}"
        );
    }

    let cfg = BertConfig {
        vocab_size: 4096,
        hidden: 64,
        layers: 2,
        heads: 2,
        ffn: 128,
        max_len: 32,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let mut rng = Rng::new(7);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = default_quantizable(&store);
    let (_, qm) = quantize_store(&store, &quantizable, &SplitQuantConfig::new(2))?;
    let pm = PackedModel::assemble(&store, &qm);
    let shards = std::env::temp_dir().join("sq_serve_paged_demo.sqsh");
    pm.save_sharded(&shards)?;
    let payload = pm.payload_bytes();
    // budget as % of the pagable encoder linears — what actually pages in
    // and out (embeddings/LN are pinned); always well under payload_bytes()
    let pagable = {
        use splitquant::shardstore::{PagedConfig, PagedModel};
        PagedModel::open(&shards, PagedConfig::default())?.pagable_bytes()
    };
    let budget = pagable * budget_pct / 100;
    assert!(budget < payload, "budget must model a machine smaller than the model");
    println!(
        "[serve_paged] packed payload {payload} B (pagable {pagable} B), residency \
         budget {budget} B ({budget_pct}% of pagable) — FP32 model would be {} B",
        store.byte_size()
    );

    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (_, pool) = emotion::load_small(1, 10, 1024);

    let mut table = Table::new(
        "paged vs resident quantized serving",
        &["mode", "budget", "QPS", "p50", "p99", "faults", "evictions", "paged in", "peak res"],
    );
    let mut labels: Vec<Vec<Option<i32>>> = Vec::new();
    for paged_mode in [false, true] {
        let serve_cfg = ServeConfig {
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_cap: 4096,
            // PR-4 engine knob + PR-3 paging knob, both surfaced here: the
            // paged mode serves the same traffic under a byte budget smaller
            // than the packed payload, on the selected micro-kernel family
            parallel: ParallelConfig { kernel, ..ParallelConfig::default() },
            residency_budget_bytes: paged_mode.then_some(budget),
            // chaos knobs apply to the paged mode only — the resident pass
            // stays the clean baseline the survivors are compared against
            retry: RetryPolicy { max_attempts: retry_max, ..RetryPolicy::default() },
            fault: (paged_mode && faults_on)
                .then(|| FaultConfig::uniform(fault_seed, fault_rate)),
            // deterministic 1-in-N shadow replays through the FP32
            // reference engine, scheduled per request sequence number
            shadow: qhealth_on
                .then_some(splitquant::qhealth::ShadowConfig { seed: 7, rate: shadow_rate }),
            ..ServeConfig::default()
        };
        let (exec, peek) = if paged_mode {
            let mut ex = QuantExecutor::paged(cfg.clone(), &shards, vec![1, 8], &serve_cfg)?;
            if qhealth_on {
                ex.enable_qhealth();
            }
            let handle = ex.model().paged().cloned();
            (Arc::new(ex), handle)
        } else {
            let mut ex = QuantExecutor::resident(cfg.clone(), &store, &qm, vec![1, 8])?;
            if qhealth_on {
                ex.enable_qhealth();
            }
            (Arc::new(ex), None)
        };
        let server = Server::start(exec, tok.clone(), serve_cfg);
        let t0 = Instant::now();
        let mut got = Vec::with_capacity(requests);
        let mut i = 0usize;
        while got.len() < requests {
            let window = 16.min(requests - got.len());
            let rxs: Vec<_> = (0..window)
                .map(|k| server.submit(&pool.texts[(i + k) % pool.len()]))
                .collect::<splitquant::Result<Vec<_>>>()?;
            i += window;
            for rx in rxs {
                let resp = rx
                    .recv_timeout(Duration::from_secs(60))
                    .map_err(|_| splitquant::Error::Coordinator("timeout".into()))?;
                match resp {
                    Ok(r) => got.push(Some(r.label)),
                    // a degraded request answers with a clean error — only
                    // acceptable while faults are being injected
                    Err(_) if faults_on && paged_mode => got.push(None),
                    Err(e) => return Err(e),
                }
            }
        }
        let wall = t0.elapsed();
        let telemetry = qhealth_on.then(|| server.telemetry_text());
        let m = server.shutdown();
        let mode_label =
            if paged_mode { format!("paged{budget_pct}") } else { "resident".to_string() };
        if let Some(text) = telemetry {
            println!("[serve_paged] telemetry[{mode_label}]:\n{text}");
        }
        if let Some(q) = &m.qhealth {
            print!("{}", splitquant::qhealth::render(q));
        }
        if metrics_json {
            println!("[serve_paged] metrics[{mode_label}] = {}", m.to_json().to_string());
        }
        if let Some(path) = &bench_json {
            let engine = format!("{:?}", kernel.effective()).to_lowercase();
            let mut rows = m.breakdown_records(&mode_label, &engine);
            if let Some(q) = &m.qhealth {
                rows.extend(splitquant::qhealth::bench_rows(q, &mode_label, &engine));
            }
            splitquant::report::bench_json::merge_write(std::path::Path::new(path), &rows)?;
            println!("[serve_paged] merged {} benchmark rows into {path}", rows.len());
        }
        let peak = peek.map(|p| p.counters().peak_resident_bytes).unwrap_or(0);
        table.row(vec![
            if paged_mode { format!("paged {budget_pct}%") } else { "resident".into() },
            if paged_mode { format!("{budget}B") } else { "∞".into() },
            format!("{:.0}", requests as f64 / wall.as_secs_f64()),
            format!("{:.1}ms", m.latency.quantile_us(0.50) as f64 / 1e3),
            format!("{:.1}ms", m.latency.quantile_us(0.99) as f64 / 1e3),
            m.shard_faults.to_string(),
            m.shard_evictions.to_string(),
            format!("{}B", m.bytes_paged_in),
            if paged_mode { format!("{peak}B") } else { "-".into() },
        ]);
        labels.push(got);
    }
    std::fs::remove_file(&shards).ok();

    let survivors = labels[1].iter().filter(|l| l.is_some()).count();
    let agree = labels[0]
        .iter()
        .zip(&labels[1])
        .filter(|(a, b)| b.is_some() && a == b)
        .count();
    println!("{}", table.render());
    if faults_on {
        println!(
            "label agreement resident vs paged survivors: {agree}/{survivors} \
             ({} degraded by injected faults)",
            requests - survivors
        );
        assert_eq!(agree, survivors, "a surviving paged request diverged from resident");
    } else {
        println!("label agreement resident vs paged: {agree}/{requests} (must be total)");
        assert_eq!(agree, requests, "paged serving diverged from resident");
    }
    if let Some(path) = trace_path {
        let snap = splitquant::trace::snapshot();
        splitquant::trace::chrome::write_chrome_trace(std::path::Path::new(&path), &snap)?;
        println!(
            "[serve_paged] wrote {} trace events ({} dropped) to {path}",
            snap.total_events(),
            snap.dropped
        );
    }
    Ok(())
}
