//! Mixed-precision autotuning walkthrough: pick per-layer bit-widths under
//! a packed-byte budget and beat the uniform low-bit baseline.
//!
//! ```sh
//! cargo run --release --example autotune_budget            # full demo
//! cargo run --release --example autotune_budget -- --smoke # CI lane, seconds
//! ```
//!
//! No artifacts needed (pure-Rust executor). The demo:
//!
//! 1. runs the **per-layer sensitivity sweep** — for every quantizable layer
//!    group and every width in {2, 4, 8}, quantize only that layer (an O(1)
//!    copy-on-write share of the FP32 store) and measure calibration-logit
//!    KL vs the FP32 reference plus the exact packed byte cost;
//! 2. allocates bits under a budget equal to the **uniform-INT4 packed
//!    size** with the greedy Lagrangian sweep → a serializable `BitPlan`;
//! 3. expands the plan through `AutoTunePass`, packs the model into the
//!    sharded `SQSH0001` format, and **validates the realized payload
//!    against the budget** through `BitPlan::validate_sharded`;
//! 4. compares argmax fidelity vs the FP32 model against uniform INT2 /
//!    INT4 / INT8 — the plan must beat uniform INT2 at ≤ uniform-INT4
//!    bytes — and merges machine-readable rows into `BENCH_autotune.json`
//!    keyed by (budget, scheme).
//!
//! Fidelity (argmax agreement with the FP32 reference) stands in for task
//! accuracy so the demo runs on a random init; with a trained checkpoint the
//! same pipeline optimizes real accuracy (see the `autotune` CLI command).

use std::path::Path;
use std::time::Instant;

use splitquant::autotune::{allocate, sweep, AutoTunePass, BitPlan, SweepConfig};
use splitquant::data::{emotion, pad_to_batches, HashTokenizer};
use splitquant::eval::{agreement_with_reference, predictions_rust};
use splitquant::model::config::BertConfig;
use splitquant::model::params::ParamStore;
use splitquant::quant::{PackedModel, QuantPipeline, SplitQuantPass};
use splitquant::report::bench_json::{merge_write, BenchRecord};
use splitquant::report::{bytes, pct, Table};
use splitquant::shardstore::ShardReader;
use splitquant::util::rng::Rng;

fn main() -> splitquant::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = if smoke {
        // tiny synthetic model: the whole walkthrough runs in seconds
        BertConfig {
            vocab_size: 512,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 16,
            num_classes: 6,
            ln_eps: 1e-12,
        }
    } else {
        BertConfig {
            vocab_size: 4096,
            hidden: 64,
            layers: 2,
            heads: 2,
            ffn: 128,
            max_len: 32,
            num_classes: 6,
            ln_eps: 1e-12,
        }
    };
    let mut rng = Rng::new(7);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (calib_set, eval_set) = emotion::load_small(1, if smoke { 32 } else { 64 }, 192);
    let (calib, _) = pad_to_batches(&calib_set, &tok, 16);
    let (eval_batches, n_eval) = pad_to_batches(&eval_set, &tok, 16);

    // ---- 1. sensitivity sweep -------------------------------------------
    let sweep_cfg = SweepConfig::default();
    let t0 = Instant::now();
    let table = sweep(&cfg, &store, &calib, &sweep_cfg)?;
    println!(
        "[autotune] swept {} layer groups x {:?} bits over {} calibration examples in {:?}",
        table.layers.len(),
        sweep_cfg.candidates,
        table.examples,
        t0.elapsed()
    );
    let mut sens = Table::new(
        "per-layer sensitivity: mean calibration KL vs FP32 (and packed bytes)",
        &["layer", "KL@INT2", "KL@INT4", "KL@INT8", "bytes@INT2", "bytes@INT8"],
    );
    for l in &table.layers {
        sens.row(vec![
            l.layer.clone(),
            format!("{:.3e}", l.options[0].kl),
            format!("{:.3e}", l.options[1].kl),
            format!("{:.3e}", l.options[2].kl),
            bytes(l.options[0].bytes),
            bytes(l.options[2].bytes),
        ]);
    }
    println!("{}", sens.render());

    // ---- 2. allocate under the uniform-INT4 budget ----------------------
    let budget = table.uniform_bytes(4).expect("4 is a sweep candidate");
    let plan = allocate(&table, budget)?;
    println!(
        "[autotune] budget {} (= uniform INT4) -> plan {} ({} planned, predicted KL {:.3e})",
        bytes(budget),
        plan.summary(),
        bytes(plan.planned_bytes),
        plan.planned_kl
    );
    // the plan serializes; a deployment host can replay it without re-sweeping
    let plan_path = std::env::temp_dir().join("sq_autotune_budget_plan.json");
    plan.save(&plan_path)?;
    let reloaded = BitPlan::load(&plan_path)?;
    std::fs::remove_file(&plan_path).ok();
    assert_eq!(reloaded.layers, plan.layers, "plan JSON round-trip drifted");

    // ---- 3. expand the plan + uniform baselines -------------------------
    let t_plan = Instant::now();
    let tuned = QuantPipeline::new()
        .pass(AutoTunePass::new(plan.clone(), sweep_cfg.base))
        .run(&store)?;
    let plan_dur = t_plan.elapsed();
    println!("[autotune] provenance: {:?}", tuned.provenance);
    let realized = tuned.quantized_model().quantized_bytes();
    assert_eq!(realized, plan.planned_bytes, "byte accounting must be exact");
    assert!(realized <= budget, "realized {realized} B blew the {budget} B budget");

    // sharded artifact: deployment-side validation of the realized payload
    let shards = std::env::temp_dir().join("sq_autotune_budget_demo.sqsh");
    let pm = PackedModel::assemble(&store, &tuned.quantized_model());
    pm.save_sharded(&shards)?;
    let validated = plan.validate_sharded(&shards)?;
    let on_disk = ShardReader::open(&shards)?.quantized_payload_bytes();
    std::fs::remove_file(&shards).ok();
    assert_eq!(validated, realized);
    println!(
        "[autotune] sharded artifact validated: {} packed payload <= {} budget \
         ({} on-disk record bytes)",
        bytes(validated),
        bytes(budget),
        bytes(on_disk)
    );

    // ---- 4. fidelity comparison + BENCH_autotune.json -------------------
    // one FP32 reference pass; every candidate scores against it
    let ref_preds = predictions_rust(&cfg, &store, &eval_batches, n_eval)?;
    let budget_key = format!("budget={budget}B");
    let mut rows: Vec<BenchRecord> = Vec::new();
    let mut cmp = Table::new(
        "budget-constrained BitPlan vs uniform bit-widths (argmax fidelity vs FP32)",
        &["scheme", "packed bytes", "vs budget", "fidelity"],
    );
    let mut uniform_agree = std::collections::BTreeMap::new();
    for bits in [2u8, 4, 8] {
        let t_u = Instant::now();
        let a = QuantPipeline::new().pass(SplitQuantPass::bits(bits)).run(&store)?;
        let dur = t_u.elapsed();
        let ub = a.quantized_model().quantized_bytes();
        let agree = agreement_with_reference(&cfg, &ref_preds, &a.eval, &eval_batches, n_eval)?;
        uniform_agree.insert(bits, agree);
        cmp.row(vec![
            format!("uniform INT{bits}"),
            bytes(ub),
            format!("{:+.1}%", 100.0 * (ub as f64 - budget as f64) / budget as f64),
            pct(agree),
        ]);
        rows.push(
            BenchRecord::new("autotune", &budget_key, &format!("uniform-int{bits}"), dur, ub)
                .with("realized_bytes", ub as f64)
                .with("agreement", agree),
        );
    }
    let plan_agree =
        agreement_with_reference(&cfg, &ref_preds, &tuned.eval, &eval_batches, n_eval)?;
    cmp.row(vec![
        format!("BitPlan {}", plan.summary()),
        bytes(realized),
        format!("{:+.1}%", 100.0 * (realized as f64 - budget as f64) / budget as f64),
        pct(plan_agree),
    ]);
    rows.push(
        BenchRecord::new("autotune", &budget_key, "bitplan", plan_dur, realized)
            .with("realized_bytes", realized as f64)
            .with("agreement", plan_agree)
            .with("planned_kl", plan.planned_kl),
    );
    println!("{}", cmp.render());

    merge_write(Path::new("BENCH_autotune.json"), &rows)?;
    println!("[autotune] rows merged into BENCH_autotune.json by (budget, scheme)");

    // the acceptance claim: at <= uniform-INT4 bytes, the plan beats the
    // uniform-INT2 baseline
    let int2 = uniform_agree[&2];
    assert!(realized <= budget);
    assert!(
        plan_agree > int2,
        "BitPlan fidelity {plan_agree} must beat uniform INT2 {int2} at <= INT4 bytes"
    );
    println!(
        "[autotune] OK: BitPlan {} at {} ({} under budget) beats uniform INT2 by {}",
        plan.summary(),
        bytes(realized),
        bytes(budget - realized),
        pct(plan_agree - int2)
    );
    Ok(())
}
