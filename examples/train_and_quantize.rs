//! **End-to-end driver** (the EXPERIMENTS.md E2E run): proves all three
//! layers compose on a real workload.
//!
//! 1. Rust generates the synthetic emotion corpus and initializes BERT-Tiny.
//! 2. The coordinator drives the AOT `bert_train_step_b32` executable
//!    (L2 JAX graph, fwd+bwd+Adam fused) for several hundred steps, logging
//!    the loss curve.
//! 3. The trained checkpoint is PTQ-quantized at INT2/4/8 with the baseline
//!    quantizer and with SplitQuant, and evaluated on the 2000-sample test
//!    set → a Table-1-shaped report.
//!
//! ```sh
//! cargo run --release --example train_and_quantize -- [steps] [task]
//! ```

use std::path::Path;

use splitquant::data::{emotion, pad_to_batches, spam, HashTokenizer, TextBatcher};
use splitquant::eval::{accuracy_rust, prepare_store, WeightMethod};
use splitquant::model::params::ParamStore;
use splitquant::quant::QConfig;
use splitquant::report::{pct, pct_delta, Table};
use splitquant::runtime::Runtime;
use splitquant::splitquant::SplitQuantConfig;
use splitquant::train::{LrSchedule, Trainer};
use splitquant::util::rng::Rng;

fn main() -> splitquant::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(400);
    let task = args.get(1).cloned().unwrap_or_else(|| "emotion".to_string());
    let seed = 0u64;

    let rt = Runtime::new(Path::new("artifacts"))?;
    let cfg = rt.manifest.bert.clone();
    println!("[e2e] BERT-Tiny: {:?}", cfg);

    // ---- data
    let (train_set, test_set) = match task.as_str() {
        "spam" => {
            let d = spam::load(seed);
            (d.clone(), d)
        }
        _ => emotion::load(seed),
    };
    println!(
        "[e2e] task={task}: {} train / {} eval samples, {} classes",
        train_set.len(),
        test_set.len(),
        train_set.num_classes
    );
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let mut batcher = TextBatcher::new(&train_set, &tok, 32);

    // ---- train through PJRT (L3 drives L2's AOT graph; Python is not running)
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let mut trainer = Trainer::new(&rt, "bert_train_step_b32", store)?;
    let schedule =
        LrSchedule::WarmupLinear { peak: 3e-4, warmup: steps / 10 + 1, floor: 3e-5 };
    println!("[e2e] training {steps} steps (loss curve):");
    let t0 = std::time::Instant::now();
    let losses = trainer.train_text(&mut batcher, steps, &schedule, &mut rng, 0, |_| {})?;
    // print a compact loss curve: every ~steps/20
    let stride = (steps / 20).max(1);
    for (i, chunk) in losses.chunks(stride).enumerate() {
        let avg: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        let bar = "#".repeat((avg * 25.0) as usize);
        println!("  steps {:4}-{:4}  loss {avg:.4} {bar}", i * stride + 1, i * stride + chunk.len());
    }
    let spent = t0.elapsed();
    println!(
        "[e2e] trained in {spent:?} ({:.2} s/step); loss {:.3} -> {:.3}",
        spent.as_secs_f64() / steps as f64,
        losses.first().unwrap(),
        trainer.final_loss(20),
    );

    // ---- evaluate FP32 (share(): an O(1) view of the trained weights; the
    // PTQ sweep below copy-on-writes only the tensors each method rewrites)
    let (batches, n) = pad_to_batches(&test_set, &tok, 32);
    let store = trainer.store.share();
    let fp32 = accuracy_rust(&cfg, &store, &batches, n, None)?;
    println!("[e2e] FP32 accuracy: {}", pct(fp32));

    // ---- PTQ sweep: the paper's Table 1 protocol
    let mut table = Table::new(
        &format!("Table-1 row — {task} (FP32 {})", pct(fp32)),
        &["Bits", "Baseline", "SplitQuant", "Diff", "Percentile99", "OCS"],
    );
    for bits in [2u8, 4, 8] {
        let acc = |m: &WeightMethod| -> splitquant::Result<f64> {
            let (s, _) = prepare_store(&store, m)?;
            accuracy_rust(&cfg, &s, &batches, n, None)
        };
        let base = acc(&WeightMethod::Baseline(QConfig::baseline(bits)))?;
        let sq = acc(&WeightMethod::SplitQuant(SplitQuantConfig::new(bits)))?;
        let pctl = acc(&WeightMethod::Baseline(QConfig::percentile(bits, 99.0)))?;
        let ocs = acc(&WeightMethod::Ocs(QConfig::baseline(bits), 0.05))?;
        table.row(vec![
            format!("INT{bits}"),
            pct(base),
            pct(sq),
            pct_delta(sq - base),
            pct(pctl),
            pct(ocs),
        ]);
    }
    println!("\n{}", table.render());
    println!("(markdown for EXPERIMENTS.md)\n{}", table.render_markdown());

    // ---- persist the checkpoint for `splitquant serve` / benches
    let out = format!("checkpoints/{task}.bin");
    trainer.store.save(Path::new(&out))?;
    println!("[e2e] checkpoint -> {out}");
    Ok(())
}
