//! Conv-splitting demo (Figure 3 + §4.1 BN folding) on the synthetic-image
//! CNN: train via the AOT executable, fold BN, quantize conv layers with and
//! without SplitQuant, compare accuracy, and run the split layers sparsely.
//!
//! ```sh
//! cargo run --release --example cnn_splitquant -- [steps]
//! ```

use std::path::Path;

use splitquant::data::images;
use splitquant::model::{CnnModel, ParamStore};
use splitquant::quant::pipeline::{BaselinePass, BnFoldWith, QuantPipeline, SplitQuantPass};
use splitquant::quant::QConfig;
use splitquant::report::{pct, pct_delta, Table};
use splitquant::runtime::Runtime;
use splitquant::splitquant as sq;
use splitquant::train::{LrSchedule, Trainer};
use splitquant::util::rng::Rng;

fn main() -> splitquant::Result<()> {
    let steps: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let seed = 0u64;
    let rt = Runtime::new(Path::new("artifacts"))?;
    let ccfg = rt.manifest.cnn.clone();

    // ---- data + training via PJRT
    let (train, test) = images::load(seed, 4096, 512);
    let mut rng = Rng::new(seed ^ 0xF00D);
    let store = ParamStore::init_cnn(&ccfg.param_order(), &mut rng);
    let mut trainer = Trainer::new(&rt, "cnn_train_step_b32", store)?;
    let schedule = LrSchedule::WarmupLinear { peak: 1e-2, warmup: 20, floor: 1e-3 };
    println!("[cnn] training {steps} steps on {} synthetic images...", train.len());
    let mut cursor = 0;
    for s in 0..steps {
        let (imgs, labels) = train.batch(cursor, 32);
        cursor = (cursor + 32) % train.len();
        let loss = trainer.step_images(&imgs, &labels, schedule.lr_at(s, steps))?;
        if (s + 1) % 100 == 0 {
            println!("  step {:4}  loss {loss:.4}", s + 1);
        }
    }
    let store = trainer.store.share();
    let fp32_model = CnnModel::new(ccfg.clone(), store.share())?;
    let fp32 = fp32_model.accuracy(&test.images, &test.labels);
    println!("[cnn] FP32 accuracy: {}", pct(fp32));

    // ---- §4.1: BN folding as a pipeline pass (function preserved)
    let bn_pairs = vec![
        ("conv1".to_string(), "bn1".to_string()),
        ("conv2".to_string(), "bn2".to_string()),
    ];
    let folded = QuantPipeline::new()
        .pass(BnFoldWith::new(bn_pairs.clone(), ccfg.bn_eps))
        .run(&store)?;
    let fold_model = CnnModel::new(ccfg.clone(), folded.eval.share())?;
    let fold_acc = fold_model.accuracy(&test.images, &test.labels);
    println!(
        "[cnn] after BN folding: {} (must match FP32 — function preserved)",
        pct(fold_acc)
    );

    // ---- PTQ composed with folding: both methods run fold-then-quantize
    // over the UNfolded store in one pipeline each
    let quantizable = sq::default_quantizable(&folded.eval);
    println!("[cnn] quantizable tensors: {quantizable:?}");
    let mut table = Table::new(
        &format!("CNN conv-split PTQ (FP32 {})", pct(fp32)),
        &["Bits", "Baseline", "SplitQuant", "Diff"],
    );
    for bits in [2u8, 4, 8] {
        let base_art = QuantPipeline::new()
            .pass(BnFoldWith::new(bn_pairs.clone(), ccfg.bn_eps))
            .pass(BaselinePass::new(QConfig::baseline(bits)))
            .run(&store)?;
        let base =
            CnnModel::new(ccfg.clone(), base_art.eval)?.accuracy(&test.images, &test.labels);
        let sq_art = QuantPipeline::new()
            .pass(BnFoldWith::new(bn_pairs.clone(), ccfg.bn_eps))
            .pass(SplitQuantPass::bits(bits))
            .run(&store)?;
        let sacc =
            CnnModel::new(ccfg.clone(), sq_art.eval)?.accuracy(&test.images, &test.labels);
        table.row(vec![
            format!("INT{bits}"),
            pct(base),
            pct(sacc),
            pct_delta(sacc - base),
        ]);
    }
    println!("\n{}", table.render());

    // ---- Figure 3 structural check: split conv == original conv
    let mut eq_rng = Rng::new(3);
    let gap = sq::equivalence::check_conv_equivalence(&sq::SplitQuantConfig::new(2), &mut eq_rng);
    println!("[cnn] Figure-3 equivalence gap (fused vs 3 materialized conv branches): {gap:.2e}");

    // ---- §6: sparse execution of split layers recovers the 3x overhead
    let fc = folded.eval.get("fc.weight")?;
    let mut sq_rng = Rng::new(4);
    let split = sq::split_quantize(fc, &sq::SplitQuantConfig::new(4), &mut sq_rng)?;
    let branches = sq::weight_split::materialize_branches(fc, &split.assignment, 3);
    let sparse = splitquant::model::sparse::SparseSplitLinear::from_dense_branches(&branches, None);
    println!(
        "[cnn] fc.weight split into 3 branches: dense 3x = {} B, CSR = {} B ({} nnz, {:.0}% of dense 3x)",
        3 * fc.byte_size(),
        sparse.byte_size(),
        sparse.nnz(),
        100.0 * sparse.byte_size() as f64 / (3 * fc.byte_size()) as f64,
    );
    trainer.store.save(Path::new("checkpoints/cnn.bin"))?;
    println!("[cnn] checkpoint -> checkpoints/cnn.bin");
    Ok(())
}
